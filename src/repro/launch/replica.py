"""Multi-replica data-parallel serving: a :class:`ReplicaPool` of N
serving workers behind one admission router.

The single-replica engine (PR 8) is throughput-capped by one GIL-bound
launcher thread; `Towards Big Topic Modeling` (PAPERS.md) motivates
scaling the same frozen-φ model over data-parallel workers, and Cappé's
online-EM argument is why placement is *free*: per-document PRNG keys and
a pinned φ snapshot make replica assignment semantically invisible — the
same document resolves to the bitwise-identical θ̂ on any replica (at
``rel_tol = 0``; see ``pad_batch`` for the ``rel_tol > 0`` caveat).

::

    submit() ──► AdmissionRouter (PR 8's slots + deadline collector)
                     │ dispatcher thread: least-loaded pick under the
                     │ per-replica in-flight cap (ReplicaBalancer)
                     ▼
       per-replica task queues ──► N replica workers
         "process" backend: one spawned process per replica, its own
           TopicServer + HotRowCache over a READONLY store attach
           (multiprocessing scales the launcher past the GIL)
         "thread" backend: one thread per replica (the device-mesh
           degenerate case — each replica pins a local jax device)
                     │ shared result queue
                     ▼
       results thread resolves futures (ThetaResult.version intact)

Fault handling reuses the PR 7 machinery: a seeded
:class:`~repro.runtime.faults.FaultPlan` in a worker fires the
``REPLICA_KILL`` point between receiving a batch and launching it
(``hard=True`` SIGKILLs the worker mid-flight).  The monitor thread
detects the loss, re-issues the dead worker's in-flight batches to
survivors — the *identical padded payload*, so re-issued results match an
unfaulted run bitwise — and respawns (or downsizes) the pool.  No
submitted Future is ever dropped.

Hot-swaps stay version-consistent across replicas: the pool subscribes
every worker to the PR 9 :class:`~repro.core.SnapshotPublisher` by
broadcasting each published snapshot (full payload + crc manifest)
through the task queues; responses carry ``ThetaResult.version``, and
pool-level ``max_staleness_versions`` is the max over replicas' launches.
"""
from __future__ import annotations

import dataclasses
import multiprocessing
import queue
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import LDAConfig, ParameterStore, PhiSnapshot
from repro.launch import serve as serve_mod
from repro.launch.serve import AdmissionRouter, TopicServer, pad_batch
from repro.runtime import faults as fault_lib


class ReplicaBalancer:
    """Pure least-loaded dispatch accounting — no threads, no I/O.

    The pool's dispatcher drives one instance under its own lock; the
    hypothesis property suite drives it directly with arbitrary
    interleavings of add / acquire / complete / remove / version notes.

    Invariants (raised on violation, never silently repaired):

    * per-replica in-flight count never goes negative — completing an
      idle replica raises;
    * :meth:`acquire` only returns a replica strictly under ``cap``, and
      always a least-loaded one (ties break to the smallest id);
    * per-replica φ version notes are monotone — a replica reporting an
      older version than it already served is a protocol violation.
    """

    def __init__(self, cap: int = 2):
        if cap < 1:
            raise ValueError("per-replica in-flight cap must be >= 1")
        self.cap = int(cap)
        self._inflight: Dict[int, int] = {}
        self._version: Dict[int, int] = {}

    # -------------------------------------------------------- membership

    def add(self, rid: int) -> None:
        if rid in self._inflight:
            raise ValueError(f"replica {rid} already registered")
        self._inflight[rid] = 0
        # a respawned rid keeps its version floor: the replacement is
        # sent the latest snapshot first, so monotonicity still holds
        self._version.setdefault(rid, -1)

    def remove(self, rid: int) -> int:
        """Deregister a (dead) replica; returns the in-flight count it
        held — the orphans the pool must re-issue."""
        orphans = self._inflight.pop(rid)
        return orphans

    def replicas(self) -> List[int]:
        return sorted(self._inflight)

    # ---------------------------------------------------------- dispatch

    def acquire(self) -> Optional[int]:
        """Least-loaded replica strictly under the cap (ties -> smallest
        id), with its in-flight count bumped; ``None`` when every replica
        is at the cap (the caller waits for a completion)."""
        free = [(n, rid) for rid, n in self._inflight.items()
                if n < self.cap]
        if not free:
            return None
        _, rid = min(free)
        self._inflight[rid] += 1
        return rid

    def acquire_specific(self, rid: int) -> bool:
        """Pin-path acquire: bump ``rid`` iff it is registered and under
        the cap (the placement-parity tests force placement with this)."""
        if self._inflight.get(rid, self.cap) >= self.cap:
            return False
        self._inflight[rid] += 1
        return True

    def complete(self, rid: int) -> None:
        if rid not in self._inflight:
            raise KeyError(f"completion for unregistered replica {rid}")
        if self._inflight[rid] <= 0:
            raise ValueError(
                f"replica {rid} completion with zero in-flight — "
                "accounting would go negative"
            )
        self._inflight[rid] -= 1

    def inflight(self, rid: int) -> int:
        return self._inflight[rid]

    def total_inflight(self) -> int:
        return sum(self._inflight.values())

    # ---------------------------------------------------------- versions

    def note_version(self, rid: int, version: int) -> None:
        old = self._version.get(rid, -1)
        if version < old:
            raise ValueError(
                f"replica {rid} φ version moved backwards "
                f"({old} -> {version}); hot-swaps must be monotone"
            )
        self._version[rid] = version

    def versions(self) -> Dict[int, int]:
        return {rid: self._version.get(rid, -1) for rid in self._inflight}

    def min_version(self) -> int:
        if not self._inflight:
            return -1
        return min(self._version.get(rid, -1) for rid in self._inflight)


@dataclasses.dataclass(frozen=True)
class ReplicaSpec:
    """Everything a worker process needs to rebuild its serving stack.

    Picklable and shipped once at spawn (the pool uses the ``spawn``
    context: a forked child would inherit jax's internal threads
    mid-state).  The worker attaches the trained store READONLY
    (:meth:`ParameterStore.attach`) — serving processes never write
    through the store; φ updates arrive via the snapshot broadcast.

    ``sim_service_ms > 0`` replaces the launch with a sleep of that
    duration (a device-model worker: the launcher waits as an async
    accelerator would run).  Used only by the ``router_saturation`` bench
    cell, where replica scaling must measure the router/dispatch path
    rather than host-core arithmetic; results are uniform θ placeholders.

    ``fault_specs`` seed a per-worker :class:`FaultPlan` that fires the
    ``REPLICA_KILL`` point (``shard`` = replica id, ``step`` = the
    worker's batch counter) between receiving a batch and launching it.
    """

    store_path: str
    cfg: LDAConfig
    vocab_capacity: int
    fit_sweeps: int = 50
    rel_tol: Optional[float] = None
    check_every: Optional[int] = None
    active_topics: int = 0
    use_pallas: Optional[bool] = None
    interpret: bool = False
    vocab_pad: int = 512
    phi_dtype: str = "float32"
    hot_rows: int = 0
    buffer_rows: int = 0
    sim_service_ms: float = 0.0
    fault_specs: Tuple[fault_lib.FaultSpec, ...] = ()

    def build_server(self) -> TopicServer:
        store = ParameterStore.attach(
            self.store_path, num_topics=self.cfg.num_topics,
            vocab_capacity=self.vocab_capacity,
            buffer_rows=self.buffer_rows,
        )
        return TopicServer(
            store, self.cfg, self.fit_sweeps,
            rel_tol=self.rel_tol, check_every=self.check_every,
            active_topics=self.active_topics, use_pallas=self.use_pallas,
            interpret=self.interpret, vocab_pad=self.vocab_pad,
            phi_dtype=self.phi_dtype, hot_rows=self.hot_rows,
        )


def snapshot_payload(snap: PhiSnapshot) -> dict:
    """Pickle-ready swap broadcast: the full φ epoch + its crc manifest.

    The worker rebuilds a :class:`PhiSnapshot` from these arrays and
    compares the recomputed crc against the publisher's — corruption
    crossing the process boundary fails loudly instead of serving
    garbage (the same contract ``TopicServer.refresh`` enforces
    in-process).
    """
    return {
        "version": snap.version,
        "phi": np.asarray(snap.phi),
        "phi_k": np.asarray(snap.phi_k),
        "step": snap.step,
        "live_vocab": snap.live_vocab,
        "write_version": snap.write_version,
        "flush_version": snap.flush_version,
        "changed_ids": np.asarray(snap.changed_ids),
        "crc": snap.crc,
    }


class _SwapMailbox:
    """A one-snapshot ``SnapshotPublisher`` stand-in inside a replica.

    ``TopicServer.subscribe``/``refresh`` only need ``latest()`` and
    ``version``; the parent's swap broadcast fills the box.  Because the
    task queue is FIFO, every batch enqueued after a swap broadcast is
    served on (at least) that version — the pool-wide staleness bound.
    """

    def __init__(self):
        self._snap: Optional[PhiSnapshot] = None
        self.version = 0

    def install(self, payload: dict) -> PhiSnapshot:
        snap = PhiSnapshot(
            version=payload["version"], phi=payload["phi"],
            phi_k=payload["phi_k"], step=payload["step"],
            live_vocab=payload["live_vocab"],
            write_version=payload["write_version"],
            flush_version=payload["flush_version"],
            changed_ids=payload["changed_ids"],
        )
        if snap.crc != payload["crc"]:
            raise RuntimeError(
                f"φ snapshot v{snap.version} failed its crc manifest "
                "crossing the process boundary — refusing to install"
            )
        self._snap = snap
        self.version = snap.version
        return snap

    def latest(self) -> Optional[PhiSnapshot]:
        return self._snap


def _serve_loop(rid: int, server: TopicServer, mailbox: _SwapMailbox,
                plan: Optional[fault_lib.FaultPlan], sim_service_ms: float,
                num_topics: int, task_q, result_q, device=None) -> None:
    """The replica message loop — identical for both backends.

    Messages in: ``("swap", payload)``, ``("prewarm", dims)``,
    ``("batch", batch_id, L, w, c, keys, filled)``, ``("stop",)``.
    Messages out: ``("ready"|"swapped"|"prewarmed"|"done"|"error"|
    "fault"|"bye", rid, ...)``.

    A ``hard=True`` kill at ``REPLICA_KILL`` SIGKILLs the process with
    the batch in flight — it is never acked, and the parent re-issues it.
    A soft kill raises :class:`InjectedFault` here: the replica reports
    and exits its loop (the thread-backend equivalent of dying).
    """
    import contextlib

    import jax

    ctx = (jax.default_device(device) if device is not None
           else contextlib.nullcontext())
    n_batches = 0
    result_q.put(("ready", rid))
    while True:
        msg = task_q.get()
        kind = msg[0]
        if kind == "stop":
            result_q.put(("bye", rid))
            return
        if kind == "swap":
            mailbox.install(msg[1])
            server.refresh()                 # between batches by FIFO order
            result_q.put(("swapped", rid, mailbox.version))
            continue
        if kind == "prewarm":
            with ctx:
                n = serve_mod.prewarm_server(server, **msg[1])
            result_q.put(("prewarmed", rid, n))
            continue
        _, batch_id, L, w, c, keys, filled = msg
        try:
            if plan is not None:
                plan.fire(fault_lib.REPLICA_KILL, shard=rid, step=n_batches)
            n_batches += 1
            t0 = time.perf_counter()
            if sim_service_ms > 0.0:
                time.sleep(sim_service_ms / 1e3)   # device-model service
                theta = np.full((w.shape[0], num_topics),
                                1.0 / num_topics, np.float32)
                version = mailbox.version if mailbox.version > 0 else -1
            else:
                with ctx:
                    theta = server.infer(w, c, key=keys)
                version = server.last_version
            secs = time.perf_counter() - t0
            cache = server.hot_cache
            cw = cache.window_stats() if cache is not None else None
            result_q.put((
                "done", rid, batch_id, np.asarray(theta[:filled]),
                version, secs,
                cw.hits if cw else 0, cw.misses if cw else 0,
            ))
        except fault_lib.InjectedFault as e:
            result_q.put(("fault", rid, str(e)))
            return                            # soft replica death
        except BaseException as e:            # deterministic failure: no
            result_q.put(("error", rid, batch_id, repr(e)))  # re-issue loop


def _replica_worker(rid: int, spec: ReplicaSpec, task_q, result_q) -> None:
    """Process-backend entry point (module-level for the spawn context)."""
    try:
        server = spec.build_server()
    except BaseException as e:
        result_q.put(("error", rid, -1, repr(e)))
        raise
    mailbox = _SwapMailbox()
    server.subscribe(mailbox, refresh=False)
    plan = (fault_lib.FaultPlan(spec.fault_specs)
            if spec.fault_specs else None)
    _serve_loop(rid, server, mailbox, plan, spec.sim_service_ms,
                spec.cfg.num_topics, task_q, result_q)


class _ProcessReplica:
    """Handle on one spawned worker process + its task queue."""

    backend = "process"

    def __init__(self, rid: int, spec: ReplicaSpec, result_q, ctx):
        self.rid = rid
        self.task_q = ctx.Queue()
        self.proc = ctx.Process(
            target=_replica_worker, args=(rid, spec, self.task_q, result_q),
            name=f"replica-{rid}", daemon=True,
        )
        self.proc.start()

    def send(self, msg) -> None:
        self.task_q.put(msg)

    def alive(self) -> bool:
        return self.proc.is_alive()

    def join(self, timeout: Optional[float] = None) -> None:
        self.proc.join(timeout)

    def kill(self) -> None:
        self.proc.kill()

    @property
    def exitcode(self):
        return self.proc.exitcode


class _ThreadReplica:
    """Handle on one in-process replica thread (device-mesh degenerate
    case: each replica optionally pins a local jax device)."""

    backend = "thread"

    def __init__(self, rid: int, server: TopicServer,
                 plan: Optional[fault_lib.FaultPlan], sim_service_ms: float,
                 num_topics: int, result_q, device=None):
        self.rid = rid
        self.task_q: "queue.Queue" = queue.Queue()
        mailbox = _SwapMailbox()
        server.subscribe(mailbox, refresh=False)
        self.thread = threading.Thread(
            target=_serve_loop,
            args=(rid, server, mailbox, plan, sim_service_ms, num_topics,
                  self.task_q, result_q, device),
            name=f"replica-{rid}", daemon=True,
        )
        self.thread.start()

    def send(self, msg) -> None:
        self.task_q.put(msg)

    def alive(self) -> bool:
        return self.thread.is_alive()

    def join(self, timeout: Optional[float] = None) -> None:
        self.thread.join(timeout)

    def kill(self) -> None:
        pass                                  # threads die via soft faults

    @property
    def exitcode(self):
        return None


class ReplicaPool:
    """N serving replicas behind one :class:`AdmissionRouter`.

    ``submit`` / ``drain`` / ``metrics`` / ``close`` mirror the
    single-replica :class:`ServingEngine` surface, so benches and callers
    swap between them freely.  See the module docstring for the thread
    and fault architecture.

    Parameters beyond the router's: ``backend`` ("process" spawns one
    worker process per replica; "thread" runs in-process replicas, the
    device-mesh degenerate case), ``max_inflight`` (per-replica dispatch
    cap — the balancer's least-loaded window), ``respawn`` (replace dead
    workers; ``False`` downsizes instead), and ``servers`` (thread
    backend only: prebuilt ``TopicServer``s, e.g. sharing the owning
    process's store for the placement-parity tests).
    """

    def __init__(self, spec: Optional[ReplicaSpec] = None, *,
                 replicas: int = 2, backend: str = "process",
                 servers: Optional[Sequence[TopicServer]] = None,
                 max_batch: int = 64, bucket_multiple: int = 16,
                 max_delay_ms: float = 5.0, max_len: int = 256,
                 queue_depth: int = 4, seed: int = 0,
                 max_inflight: int = 2, respawn: bool = True):
        if backend not in ("process", "thread"):
            raise ValueError(f"unknown replica backend {backend!r}")
        if backend == "process" and spec is None:
            raise ValueError("process backend needs a picklable ReplicaSpec")
        if servers is not None and backend != "thread":
            raise ValueError("prebuilt servers are thread-backend only")
        if servers is not None and len(servers) != replicas:
            raise ValueError("need exactly one prebuilt server per replica")
        self.spec = spec
        self.backend = backend
        self.respawn = bool(respawn)
        self.router = AdmissionRouter(
            max_batch=max_batch, bucket_multiple=bucket_multiple,
            max_delay_ms=max_delay_ms, max_len=max_len,
            queue_depth=queue_depth, seed=seed,
        )
        self.balancer = ReplicaBalancer(cap=max_inflight)
        #: test hook — force every dispatch onto one replica id (the
        #: placement-parity tests compare pinned placements bitwise)
        self.pin_replica: Optional[int] = None
        self.respawns = 0
        self.deaths: List[dict] = []
        self._soft_faults: Dict[int, str] = {}  # rid -> injected-fault detail
        self._state_lock = threading.Lock()
        self._state_cond = threading.Condition(self._state_lock)
        self._replicas: Dict[int, object] = {}
        self._inflight: Dict[int, dict] = {}   # batch_id -> dispatch info
        self._dispatched: Dict[int, int] = {}  # rid -> batches sent
        self._next_batch_id = 0
        self._ready: set = set()
        self._prewarm_acks = 0
        self._publisher = None
        self._last_swap: Optional[dict] = None
        self._swap_version = 0
        self._closing = False
        self._closed = False
        self._close_lock = threading.Lock()
        self._results_stop = threading.Event()
        self._monitor_stop = threading.Event()

        if backend == "process":
            self._ctx = multiprocessing.get_context("spawn")
            self._result_q = self._ctx.Queue()
        else:
            self._ctx = None
            self._result_q = queue.Queue()

        with self._state_cond:
            for rid in range(int(replicas)):
                server = servers[rid] if servers is not None else None
                self._replicas[rid] = self._spawn(rid, server)
                self.balancer.add(rid)
                self._dispatched[rid] = 0

        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="pool-dispatcher", daemon=True)
        self._results = threading.Thread(
            target=self._results_loop, name="pool-results", daemon=True)
        self._monitor = threading.Thread(
            target=self._monitor_loop, name="pool-monitor", daemon=True)
        self._dispatcher.start()
        self._results.start()
        self._monitor.start()

    # --------------------------------------------------------------- spawn

    def _spawn(self, rid: int, server: Optional[TopicServer] = None,
               clean: bool = False):
        """Build one replica handle.  ``clean=True`` strips the fault
        specs: the seeded chaos belongs to the original cohort, a
        respawned worker must not replay it (its batch counter restarts,
        so a concrete-step kill would fire again and again)."""
        if self.backend == "process":
            spec = self.spec
            if clean and spec.fault_specs:
                spec = dataclasses.replace(spec, fault_specs=())
            return _ProcessReplica(rid, spec, self._result_q, self._ctx)
        if server is None:
            server = self.spec.build_server()
        plan = None
        if not clean and self.spec is not None and self.spec.fault_specs:
            plan = fault_lib.FaultPlan(self.spec.fault_specs)
        sim = self.spec.sim_service_ms if self.spec is not None else 0.0
        K = (self.spec.cfg.num_topics if self.spec is not None
             else server.cfg.num_topics)
        import jax
        devs = jax.local_devices()
        device = devs[rid % len(devs)] if len(devs) > 1 else None
        return _ThreadReplica(rid, server, plan, sim, K,
                              self._result_q, device)

    def wait_ready(self, timeout: float = 120.0) -> None:
        """Block until every current replica has built its server (spawn
        + jax import ≈ 1s per process worker)."""
        deadline = time.monotonic() + timeout
        with self._state_cond:
            while not self._state_cond.wait_for(
                    lambda: self._ready >= set(self._replicas),
                    timeout=min(1.0, max(0.0, deadline - time.monotonic()))):
                if time.monotonic() >= deadline:
                    missing = set(self._replicas) - self._ready
                    raise TimeoutError(
                        f"replicas {sorted(missing)} not ready "
                        f"after {timeout}s")

    # ----------------------------------------------------------- admission

    def submit(self, word_ids: np.ndarray,
               counts: Optional[np.ndarray] = None,
               key: Optional[np.ndarray] = None) -> Future:
        """Admit one document; resolves to its (K,) θ̂ stamped with the φ
        version that produced it — same contract as the engine."""
        return self.router.submit(word_ids, counts, key)

    # ----------------------------------------------------------- lifelong

    def subscribe(self, publisher, refresh: bool = True) -> None:
        """Subscribe every replica to a :class:`SnapshotPublisher`: each
        publish is broadcast (full payload + crc) through the task
        queues.  The watcher thread picks up later publishes within its
        poll interval; per-replica swap acks feed the balancer's
        monotone version ledger."""
        self._publisher = publisher
        if refresh:
            snap = publisher.latest()
            if snap is not None:
                self._broadcast_swap(snap)
        watcher = threading.Thread(
            target=self._watch_loop, name="pool-version-watcher", daemon=True)
        watcher.start()
        self._watcher = watcher

    def _broadcast_swap(self, snap) -> None:
        payload = snapshot_payload(snap)
        with self._state_cond:
            if payload["version"] <= self._swap_version:
                return
            self._last_swap = payload
            self._swap_version = payload["version"]
            handles = list(self._replicas.values())
        for h in handles:
            h.send(("swap", payload))

    def _watch_loop(self) -> None:
        while not self._results_stop.is_set():
            pub = self._publisher
            if pub is not None and pub.version > self._swap_version:
                snap = pub.latest()
                if snap is not None:
                    self._broadcast_swap(snap)
            time.sleep(0.02)

    # ------------------------------------------------------------ dispatch

    def _choose(self) -> Optional[int]:
        pin = self.pin_replica
        if pin is not None:
            if pin in self._replicas and self.balancer.acquire_specific(pin):
                return pin
            return None
        return self.balancer.acquire()

    def _dispatch(self, L: int, reqs, w, c, keys,
                  batch_id: Optional[int] = None) -> None:
        """Assign a padded batch to a least-loaded replica (blocking while
        every replica is at its in-flight cap).  Re-issue passes the
        original ``batch_id`` and the *identical* padded arrays — the
        bitwise-parity contract."""
        with self._state_cond:
            while True:
                rid = self._choose()
                if rid is not None:
                    break
                if not self._replicas:
                    # pool fully dead and not respawning: fail, don't hang
                    if batch_id is not None:
                        self._inflight.pop(batch_id, None)
                    exc = RuntimeError(
                        "replica pool has no live replicas left")
                    self._state_cond.release()
                    try:
                        self.router.fail_batch(reqs, exc)
                    finally:
                        self._state_cond.acquire()
                    return
                self._state_cond.wait(timeout=0.05)
            if batch_id is None:
                batch_id = self._next_batch_id
                self._next_batch_id += 1
            self._inflight[batch_id] = {
                "rid": rid, "L": L, "reqs": reqs,
                "w": w, "c": c, "keys": keys, "filled": len(reqs),
            }
            self._dispatched[rid] = self._dispatched.get(rid, 0) + 1
            handle = self._replicas[rid]
        handle.send(("batch", batch_id, L, w, c, keys, len(reqs)))

    def _dispatch_loop(self) -> None:
        while True:
            item = self.router.next_batch()
            if item is None:
                return
            L, reqs = item
            w, c, keys = pad_batch(L, reqs, self.router.max_batch)
            self._dispatch(L, reqs, w, c, keys)

    # ------------------------------------------------------------- results

    def _results_loop(self) -> None:
        while True:
            try:
                msg = self._result_q.get(timeout=0.05)
            except queue.Empty:
                if self._results_stop.is_set():
                    return
                continue
            kind = msg[0]
            if kind == "done":
                _, rid, bid, theta, version, secs, ch, cm = msg
                with self._state_cond:
                    info = self._inflight.pop(bid, None)
                    if info is not None:
                        self._account_completion(info["rid"], version)
                        self._state_cond.notify_all()
                if info is None:
                    continue   # duplicate after a re-issue: drop
                pub = self._publisher
                rec = {
                    "L": info["L"], "filled": info["filled"],
                    "capacity": self.router.max_batch,
                    "launch_seconds": secs,
                    "cache_hits": ch, "cache_misses": cm,
                    "replica": rid, "version": version,
                    "published_version": (
                        pub.version if pub is not None else -1),
                }
                self.router.resolve_batch(info["reqs"], theta, version, rec)
            elif kind == "error":
                _, rid, bid, err = msg
                with self._state_cond:
                    info = self._inflight.pop(bid, None)
                    if info is not None:
                        self._account_completion(info["rid"], None)
                        self._state_cond.notify_all()
                if info is not None:
                    self.router.fail_batch(
                        info["reqs"],
                        RuntimeError(f"replica {rid} launch failed: {err}"))
            elif kind == "ready":
                with self._state_cond:
                    self._ready.add(msg[1])
                    self._state_cond.notify_all()
            elif kind == "swapped":
                _, rid, version = msg
                with self._state_cond:
                    try:
                        self.balancer.note_version(rid, version)
                    except KeyError:
                        pass                  # raced a removal
            elif kind == "prewarmed":
                with self._state_cond:
                    self._prewarm_acks += 1
                    self._state_cond.notify_all()
            elif kind == "fault":
                # a soft kill also exits the worker loop: stash the detail
                # and let the monitor's death detection record the single
                # death event (otherwise one loss counts twice)
                with self._state_cond:
                    self._soft_faults[msg[1]] = msg[2]
            # "bye": clean shutdown ack — nothing to account

    def _account_completion(self, rid: int, version: Optional[int]) -> None:
        """Balancer bookkeeping for one finished batch, tolerant of the
        replica having been removed while the result was in the queue."""
        try:
            self.balancer.complete(rid)
        except (KeyError, ValueError):
            pass
        if version is not None and version >= 0:
            try:
                self.balancer.note_version(rid, version)
            except KeyError:
                pass

    # ------------------------------------------------------------- monitor

    def _monitor_loop(self) -> None:
        while not self._monitor_stop.is_set():
            time.sleep(0.05)
            if self._closing:
                continue
            dead = []
            with self._state_cond:
                for rid, h in list(self._replicas.items()):
                    if not h.alive():
                        dead.append((rid, h))
                        del self._replicas[rid]
                        try:
                            self.balancer.remove(rid)
                        except KeyError:
                            pass
                if dead:
                    self._ready -= {rid for rid, _ in dead}
                    self._state_cond.notify_all()
            for rid, h in dead:
                self._handle_death(rid, h)

    def _handle_death(self, rid: int, handle) -> None:
        """PR 7 semantics at the pool level: record the loss, respawn (or
        downsize), then re-issue the dead worker's in-flight batches —
        identical padded payloads — so every submitted Future resolves."""
        with self._state_cond:
            detail = self._soft_faults.pop(rid, None)
        rec = {"rid": rid, "kind": "soft" if detail else "hard",
               "exitcode": handle.exitcode}
        if detail:
            rec["detail"] = detail
        self.deaths.append(rec)
        with self._state_cond:
            orphans = [(bid, info) for bid, info in self._inflight.items()
                       if info["rid"] == rid]
            respawn = self.respawn and not self._closing
            if respawn:
                self._replicas[rid] = self._spawn(rid, clean=True)
                self.balancer.add(rid)
                self.respawns += 1
                swap = self._last_swap
                self._state_cond.notify_all()
            survivors = bool(self._replicas)
        if respawn and swap is not None:
            self._replicas[rid].send(("swap", swap))
        if not survivors:
            with self._state_cond:
                for bid, info in orphans:
                    self._inflight.pop(bid, None)
            for _, info in orphans:
                self.router.fail_batch(
                    info["reqs"],
                    RuntimeError(f"replica {rid} died with no survivors"))
            return
        for bid, info in orphans:
            self._dispatch(info["L"], info["reqs"], info["w"], info["c"],
                           info["keys"], batch_id=bid)

    # ------------------------------------------------------------ plumbing

    def prewarm(self, lengths: Optional[Sequence[int]] = None,
                vocab_sizes: Optional[Sequence[int]] = None,
                timeout: float = 600.0) -> int:
        """Broadcast the (L × W_s) trace-grid compile to every replica and
        wait for the acks (each worker process owns its own jit cache)."""
        dims = {
            "max_batch": self.router.max_batch,
            "bucket_multiple": self.router.bucket_multiple,
            "max_len": self.router.max_len,
            "lengths": None if lengths is None else list(lengths),
            "vocab_sizes": (None if vocab_sizes is None
                            else list(vocab_sizes)),
        }
        with self._state_cond:
            self._prewarm_acks = 0
            handles = list(self._replicas.values())
        for h in handles:
            h.send(("prewarm", dims))
        deadline = time.monotonic() + timeout
        with self._state_cond:
            ok = self._state_cond.wait_for(
                lambda: self._prewarm_acks >= len(handles),
                timeout=deadline - time.monotonic())
        if not ok:
            raise TimeoutError("replica prewarm did not ack in time")
        return len(handles)

    def metrics(self, reset: bool = False) -> dict:
        """Router window metrics + pool aggregation: per-replica dispatch
        counts, deaths/respawns, and the balancer's version ledger
        (pool-level staleness = max over replicas, already folded into
        ``max_staleness_versions`` by the per-batch records)."""
        out = self.router.metrics(reset=reset)
        with self._state_cond:
            out.update(
                replicas=len(self._replicas),
                backend=self.backend,
                dispatch={rid: n for rid, n in sorted(
                    self._dispatched.items())},
                deaths=len(self.deaths),
                respawns=self.respawns,
                replica_versions=self.balancer.versions(),
            )
        return out

    def drain(self) -> None:
        """Block until every admitted request has resolved (including
        batches in flight at the workers — the router counts resolutions,
        not launches)."""
        self.router.drain()

    def close(self, timeout: float = 60.0) -> None:
        """Flush, dispatch, and resolve everything, then stop the world.

        Idempotent and safe under concurrent callers (the close lock
        serializes them; every caller returns only after the threads and
        workers are joined).  Order matters: the router closes first so
        the dispatcher drains every flushed bucket; worker stop messages
        go out only after the in-flight map empties, so no batch is ever
        abandoned by shutdown.
        """
        with self._close_lock:
            if self._closed:
                return
            self.router.close()
            self._dispatcher.join()
            deadline = time.monotonic() + timeout
            with self._state_cond:
                self._state_cond.wait_for(
                    lambda: not self._inflight,
                    timeout=max(0.0, deadline - time.monotonic()))
                leftovers = list(self._inflight.items())
                self._inflight.clear()
                self._closing = True
                handles = list(self._replicas.values())
            for _, info in leftovers:         # timeout path: never hang callers
                self.router.fail_batch(
                    info["reqs"],
                    RuntimeError("replica pool closed with the batch "
                                 "still in flight"))
            for h in handles:
                h.send(("stop",))
            for h in handles:
                h.join(timeout=10.0)
                if h.alive():
                    h.kill()
            self._results_stop.set()
            self._monitor_stop.set()
            self._results.join()
            self._monitor.join()
            if self.backend == "process":
                self._result_q.close()
                self._result_q.join_thread()
            self._closed = True

    def __enter__(self) -> "ReplicaPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
