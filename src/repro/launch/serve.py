"""Serving driver — topic inference for unseen documents (the paper's
deployment mode) and LM decode on reduced configs.

LDA serving = the E-step with FROZEN φ̂ (§2.4): per request batch, fit θ̂
only — the θ-only fixed point of eq. 11 with the φ M-step switched off —
and return the per-document topic mixture (eq. 9).  Requests stream
against the same disk-backed parameter access as training
(``ParameterStore``), and the fit routes through the fused inference
dispatch (``kernels.ops.infer``): convergence-stopped chunks of the
single-launch θ sweep kernel on TPU, the jnp mirror elsewhere, with the
eq. 21 log-predictive partials available in the same launch for
lifelong held-out evaluation.

The high-throughput path is :class:`ServingEngine` — continuous batching
over :class:`TopicServer`'s fixed jit shapes::

      submit() ──► admission queue (per-L-bucket in-flight slots)
                      │  collector thread: flush when a bucket fills
                      │  or its oldest request hits max_delay_ms
                      ▼
      bounded launch queue ──► launcher thread
                      │  localize_vocab → fetch φ̂ rows (HotRowCache →
                      │  ParameterStore) → pad to the (D, L, W_s) bucket
                      │  → one `_infer_local` launch (pre-warmed traces)
                      ▼
      per-request futures resolve with (θ_d, latency)

Admission never blocks on compute: while the launcher executes batch *s*,
the collector keeps admitting and assembling batch *s+1* (the launch
queue is the only backpressure).  Per-document PRNG keys make results
independent of how requests were packed into batches, so continuous
batching is semantically invisible.  ``phi_dtype`` serves a quantized
(bf16/int8) read-only φ block through the same launches; the
:class:`TrafficGenerator` drives the stack with Zipf word mixes and
Poisson arrivals for the BENCH_serve SLO cells.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import queue
import threading
import time
from concurrent.futures import Future
from typing import Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, LDA_ARCH
from repro.core import (
    HotRowCache,
    LDAConfig,
    ParameterStore,
    PhiSnapshot,
    SnapshotPublisher,
)
from repro.core import em
from repro.core.perplexity import init_theta, serving_active_topics
from repro.core.types import InferPlan, MinibatchData, uniform_responsibilities
from repro.data import synthetic_lda_corpus
from repro.kernels import ops as kops
from repro.models import build
from repro.sparse.docword import DocWordMatrix, bucketize, localize_vocab


def _round_up(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple


class ThetaResult(np.ndarray):
    """A (K,) θ mixture stamped with the committed φ snapshot version that
    produced it (−1 when serving straight from the store, i.e. not
    subscribed to a publisher).  Behaves exactly like the plain ndarray the
    engine used to resolve — the version tag rides along as an attribute."""

    version: int = -1

    @staticmethod
    def wrap(theta: np.ndarray, version: int) -> "ThetaResult":
        out = np.asarray(theta).view(ThetaResult)
        out.version = int(version)
        return out


@dataclasses.dataclass(frozen=True)
class _ServingVersion:
    """One pinned, immutable φ epoch the server launches against.

    Holds the snapshot plus its (possibly quantized) serving storage —
    built once at hot-swap (`TopicServer.refresh`) and shared by every
    launch on this version.  In-flight launches keep their reference, so a
    concurrent swap never tears a batch: rows and ``phi_k`` always come
    from the same epoch.
    """

    snapshot: PhiSnapshot
    version: int
    phi_k: np.ndarray                  # (K,) float32
    values: np.ndarray                 # (capacity, K) f32/bf16/int8 storage
    scale: Optional[np.ndarray]        # (capacity,) f32 int8 scales, or None

    def fetch_rows(self, word_ids: np.ndarray) -> np.ndarray:
        """Dequantized f32 rows of THIS version (never the live store)."""
        ids = np.asarray(word_ids, np.int64)
        rows = np.asarray(self.values[ids], np.float32)
        if self.scale is not None:
            rows = rows * self.scale[ids][:, None]
        return rows


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "fit_sweeps", "check_every", "active_topics",
                     "use_pallas", "interpret", "phi_dtype"),
)
def _infer_local(key, word_ids, counts, ev_counts, rows, phi_k, cfg,
                 fit_sweeps, check_every, rel_tol, active_topics,
                 use_pallas, interpret, phi_dtype="float32"):
    """One jitted request batch: normalise the streamed (W_s, K) view
    (eq. 10 with the *global* W smoothing mass), fit θ̂ through
    ``ops.infer`` and return the eq. 9 mixtures + diagnostics.

    ``key`` is either one batch key (legacy: one init stream folded over
    the whole (D, L, K) block — a document's init then depends on its slot
    in the batch) or a (D, 2) *per-document* key stack: each document's
    θ̂ init draws from its own stream, so the result is invariant to how
    the continuous-batching engine packed requests into slots.
    """
    if key.ndim == 2:        # per-document keys: slot-invariant init
        L = word_ids.shape[1]
        mu0 = jax.vmap(
            lambda k: uniform_responsibilities(k, (L, cfg.K), cfg.dtype)
        )(key)
        theta0 = em.fold_theta(mu0, counts)
    else:
        theta0 = init_theta(key, MinibatchData(word_ids, counts), cfg)
    phi_norm = em.normalize_phi(rows, phi_k, cfg, vocab_size=cfg.W)
    res = kops.infer(
        word_ids, counts, theta0, phi_norm,
        alpha_m1=cfg.alpha_m1, ev_counts=ev_counts,
        word_topics=(
            serving_active_topics(phi_norm, active_topics)
            if active_topics else None
        ),
        max_sweeps=fit_sweeps, check_every=check_every, rel_tol=rel_tol,
        use_pallas=use_pallas, interpret=interpret,
        plan=InferPlan(phi_dtype=phi_dtype),
        debug_checks=cfg.debug_checks,
    )
    return em.normalize_theta(res.theta, cfg), res.sweeps, res.ev_loglik


class TopicServer:
    """Batched topic-mixture inference against a (possibly disk-backed) φ̂.

    The paper's deployment mode (§2.4): per request batch, stream exactly
    the W_s touched φ̂ rows from the store, fit θ̂ with φ̂ frozen through
    the fused dispatch (``ops.infer`` — convergence-stopped instead of a
    fixed sweep budget), and return the eq. 9 topic mixtures.  Identical
    requests are deterministic: the fixed-point init key defaults to a
    fixed key and can be passed explicitly per request (it is never
    advanced by the server).

    Knobs: ``fit_sweeps`` caps the fixed point, ``rel_tol``/``check_every``
    are the §2.4 relative stop rule (defaults from the config),
    ``active_topics > 0`` restricts each word's fit support to its top-A
    topics by φ mass (the §3.1 machinery at serving time), and
    ``use_pallas``/``interpret`` force the kernel/oracle dispatch.

    Serving-specific knobs: ``phi_dtype`` stores the frozen φ block in
    bf16/int8 inside the fused kernel (dequantize-on-read; f32 results
    bitwise-unchanged by default) and ``hot_rows > 0`` layers a read-only
    hot-word row LRU (:class:`~repro.core.streaming.HotRowCache`) over
    the store, sized for the Zipf head of request traffic.
    """

    def __init__(self, store: ParameterStore, cfg: LDAConfig,
                 fit_sweeps: int = 50, *,
                 rel_tol: Optional[float] = None,
                 check_every: Optional[int] = None,
                 active_topics: int = 0,
                 use_pallas: Optional[bool] = None,
                 interpret: bool = False,
                 vocab_pad: int = 512,
                 phi_dtype: str = "float32",
                 hot_rows: int = 0):
        self.store = store
        self.cfg = cfg
        self.fit_sweeps = fit_sweeps
        self.rel_tol = cfg.ppl_rel_tol if rel_tol is None else rel_tol
        self.check_every = (
            cfg.ppl_check_every if check_every is None else check_every
        )
        self.active_topics = active_topics
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.vocab_pad = max(1, vocab_pad)   # W_s bucketing for jit reuse
        self.phi_dtype = phi_dtype
        self.hot_cache = (
            HotRowCache(store, hot_rows) if hot_rows > 0 else None
        )
        self.last_sweeps = 0                 # fixed-point sweeps of last call
        # --- lifelong publish/subscribe state ---
        self._publisher: Optional[SnapshotPublisher] = None
        self._active: Optional[_ServingVersion] = None   # pinned epoch
        self.swap_log: List[dict] = []       # one record per hot-swap
        self.last_version = -1               # version the last launch used

    # -------------------------------------------------- lifelong hot-swap

    def subscribe(self, publisher: SnapshotPublisher,
                  refresh: bool = True) -> None:
        """Serve committed φ snapshot versions from ``publisher`` instead
        of the live store — the lifelong train-while-serve mode.  Once
        subscribed, launches never read store rows again: a concurrent
        trainer can write freely and the server only moves at
        ``refresh()`` (called between launches by the engine)."""
        self._publisher = publisher
        if refresh:
            self.refresh()

    def refresh(self) -> bool:
        """Hot-swap to the newest published version, if any.  Verifies the
        snapshot's crc manifest, (re)builds the quantized serving storage,
        installs the new epoch in the hot-row cache (dropping only the
        rows the publish changed), and atomically replaces the pinned
        epoch.  Zero downtime: in-flight launches finish on the old
        version they captured.  Returns True iff a swap happened."""
        pub = self._publisher
        if pub is None:
            return False
        snap = pub.latest()
        if snap is None:
            return False
        cur = self._active
        if cur is not None and cur.version == snap.version:
            return False
        t0 = time.perf_counter()
        if not snap.verify():
            raise RuntimeError(
                f"φ snapshot v{snap.version} fails its crc manifest — "
                "torn or mutated publish; refusing to swap"
            )
        values, scale = snap.quantize(self.phi_dtype)   # re-quantize on swap
        if self.hot_cache is not None:
            self.hot_cache.install_version(
                snap.version, changed_ids=snap.changed_ids
            )
        sv = _ServingVersion(
            snapshot=snap,
            version=snap.version,
            phi_k=np.asarray(snap.phi_k, np.float32),
            values=values,
            scale=scale,
        )
        self._active = sv                    # the atomic swap point
        self.swap_log.append({
            "version": snap.version,
            "seconds": time.perf_counter() - t0,
            "changed_rows": int(len(snap.changed_ids)),
        })
        return True

    # ------------------------------------------------------------ inference

    def _fetch_rows(self, uniq: np.ndarray,
                    active: Optional[_ServingVersion] = None) -> np.ndarray:
        if self.hot_cache is not None:
            if active is not None:
                return self.hot_cache.fetch(
                    uniq, source=active, version=active.version
                )
            return self.hot_cache.fetch(uniq)
        if active is not None:
            return active.fetch_rows(uniq)
        return self.store.fetch_rows(uniq)

    def _run(self, word_ids: np.ndarray, counts: np.ndarray,
             ev_counts: Optional[np.ndarray], key: Optional[jax.Array]):
        if key is None:
            key = jax.random.PRNGKey(0)      # deterministic by default
        # pin ONE epoch for the whole launch: rows and phi_k below both come
        # from `active`, so a concurrent refresh() can never tear the batch
        active = self._active
        uniq, local = localize_vocab(word_ids)
        rows = self._fetch_rows(uniq, active)              # streamed φ̂
        # pad the local vocab to a bucket boundary so jit traces are reused
        # across requests (padded rows are never indexed by `local`)
        pad = _round_up(len(uniq), self.vocab_pad) - len(uniq)
        if pad:
            rows = np.concatenate(
                [rows, np.zeros((pad, rows.shape[1]), rows.dtype)]
            )
        args = (
            key, jnp.asarray(local), jnp.asarray(counts),
            jnp.asarray(
                ev_counts if ev_counts is not None
                else np.zeros_like(counts)
            ),
            jnp.asarray(rows),
            jnp.asarray(
                active.phi_k if active is not None else self.store.phi_k,
                jnp.float32,
            ),
            self.cfg, self.fit_sweeps, self.check_every, self.rel_tol,
            self.active_topics, self.use_pallas, self.interpret,
            self.phi_dtype,
        )
        if self.cfg.debug_checks:
            # functionalize the sanitizer checks through the jitted batch
            from jax.experimental import checkify

            err, (theta, sweeps, ev_ll) = checkify.checkify(_infer_local)(
                *args
            )
            err.throw()
        else:
            theta, sweeps, ev_ll = _infer_local(*args)
        self.last_sweeps = int(sweeps)
        self.last_version = active.version if active is not None else -1
        return np.asarray(theta), ev_ll

    def infer(self, word_ids: np.ndarray, counts: np.ndarray,
              key: Optional[jax.Array] = None) -> np.ndarray:
        """(B, L) docs -> (B, K) normalized topic mixtures θ (eq. 9)."""
        theta, _ = self._run(word_ids, counts, None, key)
        return theta

    def evaluate(self, word_ids: np.ndarray, est_counts: np.ndarray,
                 ev_counts: np.ndarray,
                 key: Optional[jax.Array] = None
                 ) -> Tuple[np.ndarray, float]:
        """Lifelong held-out evaluation: fit θ̂ on ``est_counts``, score
        ``ev_counts`` with eq. 21 in the same launch.  Returns
        ``(theta (B, K), predictive perplexity)``."""
        theta, ev_ll = self._run(word_ids, est_counts, ev_counts, key)
        ppl = float(np.exp(-float(ev_ll) / max(float(ev_counts.sum()), 1.0)))
        return theta, ppl

    def infer_stream(
        self, corpus: DocWordMatrix, doc_ids: Sequence[int],
        batch_size: int, key: Optional[jax.Array] = None,
        bucket_multiple: int = 16,
    ) -> Iterator[Tuple[Sequence[int], np.ndarray]]:
        """Batched/bucketized streaming inference over a request stream.

        Packs ``doc_ids`` into fixed-size (batch_size, L) buckets
        (``sparse.docword.bucketize``; L rounds up to ``bucket_multiple``
        and short tail batches pad with empty documents, so jit traces are
        reused across the stream), derives a per-batch key from ``key``
        (``fold_in`` by batch index — the stream is deterministic end to
        end) and yields ``(chunk_doc_ids, theta (len(chunk), K))``.
        """
        base = jax.random.PRNGKey(0) if key is None else key
        ids = list(doc_ids)
        for i, lo in enumerate(range(0, len(ids), batch_size)):
            chunk = ids[lo: lo + batch_size]
            w, c = bucketize(corpus, chunk, pad_multiple=bucket_multiple)
            if len(chunk) < batch_size:      # tail: pad with empty docs
                padding = batch_size - len(chunk)
                w = np.concatenate([w, np.zeros((padding, w.shape[1]),
                                                w.dtype)])
                c = np.concatenate([c, np.zeros((padding, c.shape[1]),
                                                c.dtype)])
            theta = self.infer(w, c, key=jax.random.fold_in(base, i))
            yield chunk, theta[: len(chunk)]


# ---------------------------------------------------------------------------
# Continuous batching — the high-throughput serving engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Request:
    """One admitted document, waiting in an in-flight slot."""

    seq: int
    word_ids: np.ndarray         # (n,) token word ids (unpadded)
    counts: np.ndarray           # (n,) token counts
    key: np.ndarray              # (2,) uint32 per-document PRNG key
    future: Future
    t_submit: float


def pad_batch(L: int, reqs: Sequence[_Request], max_batch: int
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad a flushed bucket to its ``(max_batch, L)`` jit shape.

    Tail slots are empty documents (exactly like ``infer_stream``'s tail
    padding).  The padded arrays — not the request list — are the unit of
    replica dispatch: re-issuing the identical payload after a worker
    loss reproduces the launch bitwise.  Under ``rel_tol > 0`` the
    convergence stop is batch-global, so re-issue parity REQUIRES
    resending the same padded batch, never repacking the survivors.
    """
    w = np.zeros((max_batch, L), np.int32)
    c = np.zeros((max_batch, L), np.float32)
    keys = np.zeros((max_batch, 2), np.uint32)
    for i, r in enumerate(reqs):
        w[i, : len(r.word_ids)] = r.word_ids
        c[i, : len(r.counts)] = r.counts
        keys[i] = r.key
    return w, c, keys


class AdmissionRouter:
    """Deadline-aware admission front: in-flight slots, collector thread
    and a bounded flush queue, decoupled from whatever runs the batches.

    PR 8 built this machinery inside :class:`ServingEngine`; it now
    stands alone so the multi-replica pool
    (:class:`repro.launch.replica.ReplicaPool`) can put the *identical*
    admission semantics in front of N workers:

    * ``submit`` (caller thread) appends the request to the in-flight
      slots of its document-length bucket — O(1) under a lock — stamps a
      per-document PRNG key, and returns a Future;
    * the *collector* thread flushes a bucket into the bounded queue when
      it fills ``max_batch`` slots, or when its **oldest** request has
      waited ``max_delay_ms`` (deadline-aware: a straggling slot never
      holds a full bucket hostage, a lone request never waits more than
      the deadline);
    * the single consumer (the engine's launcher thread, or the pool's
      dispatcher) pulls ``(L, reqs)`` items with :meth:`next_batch` and
      reports outcomes through :meth:`resolve_batch` /
      :meth:`fail_batch`, which keep the resolved/latency/batch
      accounting that :meth:`drain` and :meth:`metrics` read.

    ``close()`` is idempotent and safe under concurrent callers: every
    caller blocks until the collector is joined, so nobody can observe a
    half-stopped router.
    """

    def __init__(self, *, max_batch: int = 64, bucket_multiple: int = 16,
                 max_delay_ms: float = 5.0, max_len: int = 256,
                 queue_depth: int = 4, seed: int = 0):
        self.max_batch = int(max_batch)
        self.bucket_multiple = int(bucket_multiple)
        self.max_delay = float(max_delay_ms) / 1e3
        self.max_len = int(max_len)
        self.queue_depth = int(queue_depth)
        self._base_key = np.asarray(jax.random.PRNGKey(seed), np.uint32)
        self._pending: dict = {}             # L bucket -> list[_Request]
        self._seq = 0
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._queue: "queue.Queue" = queue.Queue(maxsize=self.queue_depth)
        self._stop = False
        self._resolved = 0                   # futures resolved (ok or error)
        self.latencies: List[float] = []     # per request, submit -> resolve
        self.batch_log: List[dict] = []      # per launched batch
        self._collector = threading.Thread(
            target=self._collect_loop, name="serve-collector", daemon=True
        )
        self._collector.start()

    # ------------------------------------------------------------- admission

    def _bucket(self, n: int) -> int:
        return _round_up(max(n, 1), self.bucket_multiple)

    def submit(self, word_ids: np.ndarray,
               counts: Optional[np.ndarray] = None,
               key: Optional[np.ndarray] = None) -> Future:
        """Admit one document; resolves to its (K,) normalized θ (eq. 9)."""
        w = np.asarray(word_ids, np.int32).ravel()
        c = (np.ones(len(w), np.float32) if counts is None
             else np.asarray(counts, np.float32).ravel())
        if len(w) > self.max_len:
            raise ValueError(
                f"document has {len(w)} tokens > engine max_len "
                f"{self.max_len}; raise max_len at construction"
            )
        fut: Future = Future()
        with self._cond:
            if self._stop:
                raise RuntimeError("admission router is closed")
            seq = self._seq
            self._seq += 1
            if key is None:
                # distinct per-request stream, no per-request jax dispatch
                key = self._base_key.copy()
                key[1] ^= np.uint32(seq)
            req = _Request(seq, w, c, np.asarray(key, np.uint32), fut,
                           time.perf_counter())
            self._pending.setdefault(self._bucket(len(w)), []).append(req)
            self._cond.notify()
        return fut

    # ------------------------------------------------------------- collector

    def _collect_loop(self) -> None:
        while True:
            flush: List[Tuple[int, List[_Request]]] = []
            with self._cond:
                while True:
                    if self._stop and not self._pending:
                        break
                    now = time.perf_counter()
                    deadline = None
                    for L, reqs in self._pending.items():
                        if len(reqs) >= self.max_batch or self._stop:
                            flush.append((L, reqs[: self.max_batch]))
                            rest = reqs[self.max_batch:]
                            self._pending[L] = rest
                            continue
                        age_out = reqs[0].t_submit + self.max_delay
                        if age_out <= now:
                            flush.append((L, reqs))
                            self._pending[L] = []
                        elif deadline is None or age_out < deadline:
                            deadline = age_out
                    self._pending = {
                        L: r for L, r in self._pending.items() if r
                    }
                    if flush or (self._stop and not self._pending):
                        break
                    self._cond.wait(
                        timeout=None if deadline is None else deadline - now
                    )
                stopping = self._stop and not self._pending
            for item in flush:       # bounded put OUTSIDE the lock:
                self._queue.put(item)  # backpressure must not stall submit()
            if stopping and not flush:
                self._queue.put(None)
                return

    # -------------------------------------------------------------- consumer

    def next_batch(self) -> Optional[Tuple[int, List[_Request]]]:
        """Block for the next flushed ``(L, reqs)`` bucket.  ``None`` is
        the shutdown sentinel: admission stopped and every pending slot
        has been flushed ahead of it."""
        return self._queue.get()

    def resolve_batch(self, reqs: Sequence[_Request], thetas,
                      version: int, rec: dict) -> None:
        """Resolve a launched bucket and commit its accounting (batch
        record + per-request latencies).  Resolutions are counted one by
        one: if ``set_result`` ever raises mid-loop (e.g. a cancelled
        future), the already-resolved prefix must still reach
        ``_resolved`` or ``drain()`` hangs forever on the lost counts."""
        t1 = time.perf_counter()
        ok = 0
        try:
            for i, r in enumerate(reqs):
                r.future.set_result(
                    ThetaResult.wrap(np.array(thetas[i]), version)
                )
                ok += 1
        finally:
            with self._lock:
                self._resolved += ok
                self.batch_log.append(rec)
                self.latencies.extend(t1 - r.t_submit for r in reqs)

    def fail_batch(self, reqs: Sequence[_Request],
                   exc: BaseException) -> None:
        """Resolve a failed bucket with ``exc`` — never hang the callers."""
        n_err = 0
        for r in reqs:
            if not r.future.done():
                r.future.set_exception(exc)
                n_err += 1
        with self._lock:
            self._resolved += n_err

    # ------------------------------------------------------------ accounting

    def metrics(self, reset: bool = False) -> dict:
        """Latency/throughput/cache summary over the recorded window."""
        with self._lock:
            lats = np.asarray(self.latencies, np.float64)  # lint: host-f64
            log = list(self.batch_log)
            if reset:
                self.latencies = []
                self.batch_log = []
        out = {
            "requests": int(lats.size),
            "batches": len(log),
            "mean_fill": (
                float(np.mean([b["filled"] for b in log])) if log else 0.0
            ),
            "cache_hits": int(sum(b["cache_hits"] for b in log)),
            "cache_misses": int(sum(b["cache_misses"] for b in log)),
        }
        # staleness bound actually observed: how many committed versions
        # behind the newest publish each launch served (lifelong mode only)
        stale = [
            b["published_version"] - b["version"]
            for b in log
            if b.get("version", -1) >= 0 and b.get("published_version", -1) >= 0
        ]
        if stale:
            out["max_staleness_versions"] = int(max(stale))
        if lats.size:
            out.update(
                p50_ms=float(np.percentile(lats, 50) * 1e3),
                p99_ms=float(np.percentile(lats, 99) * 1e3),
                mean_ms=float(lats.mean() * 1e3),
            )
        return out

    def drain(self) -> None:
        """Block until every admitted request has resolved."""
        while True:
            with self._lock:
                idle = not self._pending and self._queue.empty()
                resolved, admitted = self._resolved, self._seq
            if idle and resolved >= admitted:
                return
            time.sleep(0.001)

    def close(self) -> None:
        """Stop admission, flush the remaining slots, join the collector.

        Idempotent AND safe under concurrent callers: every caller blocks
        on the join (``Thread.join`` is multi-caller safe), so no caller
        returns while the collector is still flushing.
        """
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        self._collector.join()


def prewarm_server(srv: TopicServer, *, max_batch: int,
                   bucket_multiple: int, max_len: int,
                   lengths: Optional[Sequence[int]] = None,
                   vocab_sizes: Optional[Sequence[int]] = None) -> int:
    """Compile one server's (L-bucket × W_s-bucket) trace grid.

    Shared by ``ServingEngine.prewarm`` and each pool replica — a worker
    process owns its own jit cache, so the replica pool prewarms per
    worker with exactly these launches.  Returns the launch count and
    resets the cache/store stat windows so warm-up traffic doesn't
    pollute the serving counters (both resets take their owner's lock —
    a concurrent launcher fetch never observes a half-replaced stats
    object).
    """
    if lengths is None:
        lengths = range(bucket_multiple, max_len + 1, bucket_multiple)
    count = 0
    for L in lengths:
        Lb = _round_up(max(L, 1), bucket_multiple)
        if Lb != L:
            continue
        vs = vocab_sizes
        if vs is None:
            reach = min(srv.cfg.W, max_batch * Lb)
            vs = range(srv.vocab_pad,
                       _round_up(reach, srv.vocab_pad) + 1,
                       srv.vocab_pad)
        for ws in vs:
            n = min(ws, srv.cfg.W, max_batch * Lb)
            if _round_up(n, srv.vocab_pad) != ws:
                continue              # bucket not reachable at this (D, L)
            w = (np.arange(max_batch * Lb, dtype=np.int64) % n)
            w = w.reshape(max_batch, Lb).astype(np.int32)
            c = np.ones_like(w, np.float32)
            keys = np.zeros((max_batch, 2), np.uint32)
            srv.infer(w, c, key=jnp.asarray(keys))
            count += 1
    if srv.hot_cache is not None:
        srv.hot_cache.reset_stats()
    srv.store.stats_window(reset=True)
    return count


class ServingEngine:
    """Continuous batching over :class:`TopicServer`'s fixed jit shapes.

    Admission (in-flight slots, deadline-aware collector, bounded launch
    queue, per-document PRNG keys) is an :class:`AdmissionRouter`; the
    engine adds the single *launcher* thread that consumes flushed
    buckets, pads each to its (``max_batch``, L-bucket) jit shape
    (:func:`pad_batch`) and runs one ``_infer_local`` launch per bucket.
    Admission never blocks on compute: the bounded queue is the only
    backpressure.

    Every request gets a *per-document* PRNG key, so a document's θ is
    independent of which slot/batch the collector packed it into —
    continuous batching is semantically invisible (bitwise, under
    ``rel_tol=0``).  ``prewarm()`` compiles the whole (L-bucket ×
    W_s-bucket) trace grid up front; ``compile_count()`` exposes the
    jit-cache size so benches can assert no recompilation under traffic.
    """

    def __init__(self, server: TopicServer, *,
                 max_batch: int = 64,
                 bucket_multiple: int = 16,
                 max_delay_ms: float = 5.0,
                 max_len: int = 256,
                 queue_depth: int = 4,
                 seed: int = 0):
        self.server = server
        self.router = AdmissionRouter(
            max_batch=max_batch, bucket_multiple=bucket_multiple,
            max_delay_ms=max_delay_ms, max_len=max_len,
            queue_depth=queue_depth, seed=seed,
        )
        self.max_batch = self.router.max_batch
        self.bucket_multiple = self.router.bucket_multiple
        self.max_delay = self.router.max_delay
        self.max_len = self.router.max_len
        self.queue_depth = self.router.queue_depth
        self._launcher = threading.Thread(
            target=self._launch_loop, name="serve-launcher", daemon=True
        )
        self._launcher.start()

    # ------------------------------------------------------------- admission

    # Accounting lives on the router; these delegations keep the PR-8
    # test/bench surface (eng._resolved, eng._seq, eng.batch_log,
    # eng.latencies) stable.

    @property
    def _resolved(self) -> int:
        return self.router._resolved

    @property
    def _seq(self) -> int:
        return self.router._seq

    @property
    def batch_log(self) -> List[dict]:
        return self.router.batch_log

    @property
    def latencies(self) -> List[float]:
        return self.router.latencies

    def _bucket(self, n: int) -> int:
        return self.router._bucket(n)

    def submit(self, word_ids: np.ndarray, counts: Optional[np.ndarray] = None,
               key: Optional[np.ndarray] = None) -> Future:
        """Admit one document; resolves to its (K,) normalized θ (eq. 9)."""
        return self.router.submit(word_ids, counts, key)

    # -------------------------------------------------------------- launcher

    def _launch_loop(self) -> None:
        while True:
            item = self.router.next_batch()
            if item is None:
                return
            L, reqs = item
            try:
                # hot-swap point: the launcher is the only thread that
                # launches, so swapping BETWEEN launches gives zero
                # downtime — no launch ever straddles two versions
                self.server.refresh()
                self._launch(L, reqs)
            except BaseException as e:   # resolve, never hang the callers
                self.router.fail_batch(reqs, e)

    def _launch(self, L: int, reqs: List[_Request]) -> None:
        w, c, keys = pad_batch(L, reqs, self.max_batch)
        t0 = time.perf_counter()
        theta = self.server.infer(w, c, key=jnp.asarray(keys))
        t1 = time.perf_counter()
        version = self.server.last_version
        pub = self.server._publisher
        cache = self.server.hot_cache
        cw = cache.window_stats() if cache is not None else None
        rec = {
            "L": L, "filled": len(reqs), "capacity": self.max_batch,
            "launch_seconds": t1 - t0,
            "cache_hits": cw.hits if cw else 0,
            "cache_misses": cw.misses if cw else 0,
            # staleness audit trail: the version this launch served vs the
            # newest committed version at launch time
            "version": version,
            "published_version": pub.version if pub is not None else -1,
        }
        self.router.resolve_batch(reqs, theta, version, rec)

    # -------------------------------------------------------------- plumbing

    def prewarm(self, lengths: Optional[Sequence[int]] = None,
                vocab_sizes: Optional[Sequence[int]] = None) -> int:
        """Compile the (L-bucket × W_s-bucket) trace grid up front.

        Defaults cover every shape the admission path can produce: L
        buckets are the ``bucket_multiple`` grid up to ``max_len``; W_s
        buckets are the ``vocab_pad`` grid up to the largest unique vocab
        a full batch can touch (min(W, max_batch·L)).  Returns the jit
        cache size afterwards — under subsequent traffic
        ``compile_count()`` must not move past it.
        """
        prewarm_server(self.server, max_batch=self.max_batch,
                       bucket_multiple=self.bucket_multiple,
                       max_len=self.max_len, lengths=lengths,
                       vocab_sizes=vocab_sizes)
        return self.compile_count()

    @staticmethod
    def compile_count() -> int:
        """Size of ``_infer_local``'s jit cache — the recompilation probe."""
        return _infer_local._cache_size()

    def metrics(self, reset: bool = False) -> dict:
        """Latency/throughput/cache summary over the recorded window."""
        return self.router.metrics(reset=reset)

    def drain(self) -> None:
        """Block until every admitted request has resolved."""
        self.router.drain()

    def close(self) -> None:
        """Flush remaining slots, stop both threads.

        Idempotent AND safe under concurrent callers: every caller blocks
        until both the collector and the launcher are joined.  (The PR-8
        version let a second closer return as soon as it saw the stop
        flag, while the first was still joining — double-close by
        thread-join luck; the threaded regression test in
        ``test_serving_engine.py`` pins the fix.)
        """
        self.router.close()
        self._launcher.join()

    def __enter__(self) -> "ServingEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# Synthetic traffic — Zipf word mix, Poisson arrivals, QPS ramps
# ---------------------------------------------------------------------------


class TrafficGenerator:
    """Deterministic synthetic request traffic for the serving bench.

    Documents draw their tokens from a Zipf(``zipf_exponent``) word
    distribution over a seeded permutation of the vocabulary (the
    realistic skew the hot-row cache exploits); arrivals are Poisson —
    i.i.d. exponential gaps at each stage's rate — with ``stages`` giving
    a QPS ramp as ``(qps, num_requests)`` segments.  ``trace`` precomputes
    everything (sampling never runs inside the timed loop);
    ``replay`` submits a trace either paced (latency measurement) or
    back-to-back (sustained-throughput measurement).
    """

    def __init__(self, vocab_size: int, *,
                 zipf_exponent: float = 1.1,
                 doc_len: Tuple[int, int] = (16, 64),
                 seed: int = 0):
        self.vocab = int(vocab_size)
        self.doc_len = doc_len
        self.rng = np.random.default_rng(seed)
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)  # lint: host-f64
        p = ranks ** -float(zipf_exponent)
        self._p = p / p.sum()
        self._word_of_rank = self.rng.permutation(self.vocab)

    def document(self) -> Tuple[np.ndarray, np.ndarray]:
        """One bag-of-words request: (unique word ids, counts)."""
        lo, hi = self.doc_len
        n_tokens = int(self.rng.integers(lo, hi + 1))
        ranks = self.rng.choice(self.vocab, size=n_tokens, p=self._p)
        uniq, counts = np.unique(self._word_of_rank[ranks],
                                 return_counts=True)
        return uniq.astype(np.int32), counts.astype(np.float32)

    def trace(self, stages: Sequence[Tuple[float, int]]
              ) -> List[Tuple[float, np.ndarray, np.ndarray]]:
        """Precompute ``(arrival_seconds, word_ids, counts)`` requests for
        a QPS ramp of ``(qps, num_requests)`` stages."""
        out = []
        t = 0.0
        for qps, n in stages:
            gaps = self.rng.exponential(1.0 / float(qps), int(n))
            for g in gaps:
                t += float(g)
                w, c = self.document()
                out.append((t, w, c))
        return out

    @staticmethod
    def replay(trace, submit, pace: bool = True) -> List[Future]:
        """Drive ``submit(word_ids, counts)`` with a precomputed trace.

        ``pace=True`` honours the arrival timestamps (open-loop latency
        measurement: late arrivals are submitted immediately, queueing
        delay counts against the server); ``pace=False`` submits
        back-to-back (closed-loop sustained-QPS measurement).
        """
        futures = []
        t0 = time.perf_counter()
        for t_arr, w, c in trace:
            if pace:
                delay = t0 + t_arr - time.perf_counter()
                if delay > 0:
                    time.sleep(delay)
            futures.append(submit(w, c))
        return futures


def serve_traffic(args, server: TopicServer) -> None:
    """Drive the continuous-batching engine — or, with ``--replicas N``,
    the multi-replica pool — with synthetic Zipf/Poisson traffic and
    report the SLO numbers (p50/p99 latency, QPS, cache)."""
    gen = TrafficGenerator(args.vocab, seed=123)
    trace = gen.trace([(args.qps, args.requests)])
    replicas = int(getattr(args, "replicas", 1) or 1)
    if replicas > 1:
        # imported lazily: replica.py imports this module
        from repro.launch.replica import ReplicaPool, ReplicaSpec

        spec = ReplicaSpec(
            store_path=args.workdir, cfg=server.cfg,
            vocab_capacity=args.vocab, fit_sweeps=server.fit_sweeps,
            rel_tol=server.rel_tol, check_every=server.check_every,
            active_topics=server.active_topics, vocab_pad=server.vocab_pad,
            phi_dtype=server.phi_dtype, hot_rows=args.hot_rows,
        )
        backend = getattr(args, "replica_backend", "process")
        with ReplicaPool(spec, replicas=replicas, backend=backend,
                         max_batch=args.batch,
                         max_delay_ms=args.max_delay_ms,
                         max_len=_round_up(gen.doc_len[1], 16)) as pool:
            pool.wait_ready()
            t0 = time.time()
            futs = TrafficGenerator.replay(trace, pool.submit,
                                           pace=args.pace)
            for f in futs:
                f.result()
            dt = time.time() - t0
            m = pool.metrics()
        print(f"served {m['requests']} requests in {dt:.2f}s over "
              f"{replicas} {backend} replicas "
              f"({m['requests']/dt:.1f} QPS sustained, target {args.qps})")
        print(f"  latency p50 {m.get('p50_ms', 0):.1f}ms  "
              f"p99 {m.get('p99_ms', 0):.1f}ms  "
              f"batches {m['batches']} (mean fill {m['mean_fill']:.1f}); "
              f"dispatch {m['dispatch']}, deaths {m['deaths']}, "
              f"respawns {m['respawns']}")
        return
    with ServingEngine(server, max_batch=args.batch,
                       max_delay_ms=args.max_delay_ms,
                       max_len=_round_up(gen.doc_len[1], 16)) as eng:
        compiled = eng.prewarm()
        t0 = time.time()
        futs = TrafficGenerator.replay(trace, eng.submit, pace=args.pace)
        for f in futs:
            f.result()
        dt = time.time() - t0
        m = eng.metrics()
        assert eng.compile_count() == compiled, "recompiled under traffic!"
    print(f"served {m['requests']} requests in {dt:.2f}s "
          f"({m['requests']/dt:.1f} QPS sustained, target {args.qps})")
    print(f"  latency p50 {m.get('p50_ms', 0):.1f}ms  "
          f"p99 {m.get('p99_ms', 0):.1f}ms  "
          f"batches {m['batches']} (mean fill {m['mean_fill']:.1f})")
    if server.hot_cache is not None:
        s = server.hot_cache.stats
        print(f"  hot-row cache: {s.hits} hits / {s.misses} misses "
              f"({100 * s.hit_rate:.1f}%)")


def serve_lda(args) -> None:
    cfg = LDAConfig(num_topics=args.topics, vocab_size=args.vocab)
    store = ParameterStore(args.workdir, num_topics=args.topics,
                           vocab_capacity=args.vocab,
                           buffer_rows=args.buffer_rows)
    if store.phi_k.sum() == 0:
        raise SystemExit(
            f"no trained φ̂ under {args.workdir}; run launch/train.py first"
        )
    server = TopicServer(store, cfg, active_topics=args.active_topics,
                         phi_dtype=args.phi_dtype, hot_rows=args.hot_rows)
    if args.traffic:
        serve_traffic(args, server)
        return
    corpus, _ = synthetic_lda_corpus(args.requests, args.vocab,
                                     args.topics, seed=123)
    ids = list(range(corpus.num_docs))
    t0 = time.time()
    for chunk, theta in server.infer_stream(corpus, ids, args.batch):
        top = np.argsort(-theta, axis=1)[:, :3]
        if chunk[0] == ids[0]:
            for d in range(min(4, len(chunk))):
                mix = ", ".join(
                    f"k{int(k)}:{theta[d, k]:.2f}" for k in top[d]
                )
                print(f"  doc{chunk[d]:4d} top topics: {mix}")
    dt = time.time() - t0
    print(f"served {len(ids)} docs in {dt:.2f}s "
          f"({len(ids)/dt:.1f} docs/s, batch={args.batch}, "
          f"{server.last_sweeps} fixed-point sweeps on the last batch)")


def serve_lm(args) -> None:
    cfg = ARCHS[args.arch].reduced()
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    B, prompt_len, gen = args.batch, 16, args.gen_tokens

    batch = {"tokens": jnp.ones((B, prompt_len), jnp.int32)}
    if cfg.frontend == "image_patches":
        batch["image_embeds"] = jnp.ones(
            (B, cfg.image_tokens, cfg.d_model), jnp.float32) * 0.01
    logits, pre_caches = model.prefill(params, batch)
    cache = model.init_cache(B, prompt_len + gen)
    cache = jax.tree.map(
        lambda dst, src: jax.lax.dynamic_update_slice(
            dst, src.astype(dst.dtype), (0,) * dst.ndim
        ) if dst.ndim == src.ndim else dst,
        cache, pre_caches,
    )

    @jax.jit
    def step(params, cache, tok, pos):
        b = {"tokens": tok}
        if cfg.frontend == "image_patches":
            b["image_embeds"] = batch["image_embeds"]
        lg, cache = model.decode_step(params, cache, b, pos)
        nxt = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache

    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out: List[np.ndarray] = [np.asarray(tok)]
    t0 = time.time()
    for i in range(gen):
        tok, cache = step(params, cache, tok, jnp.int32(prompt_len + i))
        out.append(np.asarray(tok))
    dt = time.time() - t0
    print(f"{args.arch}: generated {gen}×{B} tokens in {dt:.2f}s "
          f"({B*gen/dt:.1f} tok/s); sample: {np.concatenate(out,1)[0][:16]}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=LDA_ARCH)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--topics", type=int, default=100)
    ap.add_argument("--vocab", type=int, default=5000)
    ap.add_argument("--buffer-rows", type=int, default=2048)
    ap.add_argument("--active-topics", type=int, default=0,
                    help="restrict each word's fit support to its top-A "
                         "topics by trained φ mass (0 = dense fit)")
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--gen-tokens", type=int, default=32)
    ap.add_argument("--traffic", action="store_true",
                    help="drive the continuous-batching engine with "
                         "synthetic Zipf/Poisson traffic and report "
                         "p50/p99 latency + sustained QPS")
    ap.add_argument("--qps", type=float, default=200.0,
                    help="offered request rate for --traffic")
    ap.add_argument("--pace", action="store_true",
                    help="honour arrival timestamps (open-loop latency "
                         "run) instead of submitting back-to-back")
    ap.add_argument("--max-delay-ms", type=float, default=5.0,
                    help="continuous-batching flush deadline")
    ap.add_argument("--phi-dtype", default="float32",
                    choices=("float32", "bfloat16", "int8"),
                    help="serving storage dtype of the frozen φ block")
    ap.add_argument("--hot-rows", type=int, default=0,
                    help="capacity of the serving hot-word φ-row cache "
                         "(0 = disabled)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve --traffic through a ReplicaPool of N "
                         "data-parallel workers (1 = the single-replica "
                         "engine)")
    ap.add_argument("--replica-backend", default="process",
                    choices=("process", "thread"),
                    help="replica isolation: one spawned process per "
                         "replica (scales past the GIL) or in-process "
                         "threads (the device-mesh degenerate case)")
    args = ap.parse_args()
    if args.arch == LDA_ARCH:
        serve_lda(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
