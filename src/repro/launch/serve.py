"""Serving driver — topic inference for unseen documents (the paper's
deployment mode) and LM decode on reduced configs.

LDA serving = the E-step with FROZEN φ̂ (§2.4): per request batch, fit θ̂
only — the θ-only fixed point of eq. 11 with the φ M-step switched off —
and return the per-document topic mixture (eq. 9).  Requests stream
against the same disk-backed parameter access as training
(``ParameterStore``), and the fit routes through the fused inference
dispatch (``kernels.ops.infer``): convergence-stopped chunks of the
single-launch θ sweep kernel on TPU, the jnp mirror elsewhere, with the
eq. 21 log-predictive partials available in the same launch for
lifelong held-out evaluation.
"""
from __future__ import annotations

import argparse
import functools
import time
from typing import Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, LDA_ARCH
from repro.core import LDAConfig, ParameterStore
from repro.core import em
from repro.core.perplexity import init_theta, serving_active_topics
from repro.core.types import MinibatchData
from repro.data import synthetic_lda_corpus
from repro.kernels import ops as kops
from repro.models import build
from repro.sparse.docword import DocWordMatrix, bucketize, localize_vocab


def _round_up(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "fit_sweeps", "check_every", "active_topics",
                     "use_pallas", "interpret"),
)
def _infer_local(key, word_ids, counts, ev_counts, rows, phi_k, cfg,
                 fit_sweeps, check_every, rel_tol, active_topics,
                 use_pallas, interpret):
    """One jitted request batch: normalise the streamed (W_s, K) view
    (eq. 10 with the *global* W smoothing mass), fit θ̂ through
    ``ops.infer`` and return the eq. 9 mixtures + diagnostics."""
    phi_norm = em.normalize_phi(rows, phi_k, cfg, vocab_size=cfg.W)
    res = kops.infer(
        word_ids, counts, init_theta(key, MinibatchData(word_ids, counts),
                                     cfg), phi_norm,
        alpha_m1=cfg.alpha_m1, ev_counts=ev_counts,
        word_topics=(
            serving_active_topics(phi_norm, active_topics)
            if active_topics else None
        ),
        max_sweeps=fit_sweeps, check_every=check_every, rel_tol=rel_tol,
        use_pallas=use_pallas, interpret=interpret,
        debug_checks=cfg.debug_checks,
    )
    return em.normalize_theta(res.theta, cfg), res.sweeps, res.ev_loglik


class TopicServer:
    """Batched topic-mixture inference against a (possibly disk-backed) φ̂.

    The paper's deployment mode (§2.4): per request batch, stream exactly
    the W_s touched φ̂ rows from the store, fit θ̂ with φ̂ frozen through
    the fused dispatch (``ops.infer`` — convergence-stopped instead of a
    fixed sweep budget), and return the eq. 9 topic mixtures.  Identical
    requests are deterministic: the fixed-point init key defaults to a
    fixed key and can be passed explicitly per request (it is never
    advanced by the server).

    Knobs: ``fit_sweeps`` caps the fixed point, ``rel_tol``/``check_every``
    are the §2.4 relative stop rule (defaults from the config),
    ``active_topics > 0`` restricts each word's fit support to its top-A
    topics by φ mass (the §3.1 machinery at serving time), and
    ``use_pallas``/``interpret`` force the kernel/oracle dispatch.
    """

    def __init__(self, store: ParameterStore, cfg: LDAConfig,
                 fit_sweeps: int = 50, *,
                 rel_tol: Optional[float] = None,
                 check_every: Optional[int] = None,
                 active_topics: int = 0,
                 use_pallas: Optional[bool] = None,
                 interpret: bool = False,
                 vocab_pad: int = 512):
        self.store = store
        self.cfg = cfg
        self.fit_sweeps = fit_sweeps
        self.rel_tol = cfg.ppl_rel_tol if rel_tol is None else rel_tol
        self.check_every = (
            cfg.ppl_check_every if check_every is None else check_every
        )
        self.active_topics = active_topics
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.vocab_pad = max(1, vocab_pad)   # W_s bucketing for jit reuse
        self.last_sweeps = 0                 # fixed-point sweeps of last call

    def _run(self, word_ids: np.ndarray, counts: np.ndarray,
             ev_counts: Optional[np.ndarray], key: Optional[jax.Array]):
        if key is None:
            key = jax.random.PRNGKey(0)      # deterministic by default
        uniq, local = localize_vocab(word_ids)
        rows = self.store.fetch_rows(uniq)                 # streamed φ̂
        # pad the local vocab to a bucket boundary so jit traces are reused
        # across requests (padded rows are never indexed by `local`)
        pad = _round_up(len(uniq), self.vocab_pad) - len(uniq)
        if pad:
            rows = np.concatenate(
                [rows, np.zeros((pad, rows.shape[1]), rows.dtype)]
            )
        args = (
            key, jnp.asarray(local), jnp.asarray(counts),
            jnp.asarray(
                ev_counts if ev_counts is not None
                else np.zeros_like(counts)
            ),
            jnp.asarray(rows), jnp.asarray(self.store.phi_k, jnp.float32),
            self.cfg, self.fit_sweeps, self.check_every, self.rel_tol,
            self.active_topics, self.use_pallas, self.interpret,
        )
        if self.cfg.debug_checks:
            # functionalize the sanitizer checks through the jitted batch
            from jax.experimental import checkify

            err, (theta, sweeps, ev_ll) = checkify.checkify(_infer_local)(
                *args
            )
            err.throw()
        else:
            theta, sweeps, ev_ll = _infer_local(*args)
        self.last_sweeps = int(sweeps)
        return np.asarray(theta), ev_ll

    def infer(self, word_ids: np.ndarray, counts: np.ndarray,
              key: Optional[jax.Array] = None) -> np.ndarray:
        """(B, L) docs -> (B, K) normalized topic mixtures θ (eq. 9)."""
        theta, _ = self._run(word_ids, counts, None, key)
        return theta

    def evaluate(self, word_ids: np.ndarray, est_counts: np.ndarray,
                 ev_counts: np.ndarray,
                 key: Optional[jax.Array] = None
                 ) -> Tuple[np.ndarray, float]:
        """Lifelong held-out evaluation: fit θ̂ on ``est_counts``, score
        ``ev_counts`` with eq. 21 in the same launch.  Returns
        ``(theta (B, K), predictive perplexity)``."""
        theta, ev_ll = self._run(word_ids, est_counts, ev_counts, key)
        ppl = float(np.exp(-float(ev_ll) / max(float(ev_counts.sum()), 1.0)))
        return theta, ppl

    def infer_stream(
        self, corpus: DocWordMatrix, doc_ids: Sequence[int],
        batch_size: int, key: Optional[jax.Array] = None,
        bucket_multiple: int = 16,
    ) -> Iterator[Tuple[Sequence[int], np.ndarray]]:
        """Batched/bucketized streaming inference over a request stream.

        Packs ``doc_ids`` into fixed-size (batch_size, L) buckets
        (``sparse.docword.bucketize``; L rounds up to ``bucket_multiple``
        and short tail batches pad with empty documents, so jit traces are
        reused across the stream), derives a per-batch key from ``key``
        (``fold_in`` by batch index — the stream is deterministic end to
        end) and yields ``(chunk_doc_ids, theta (len(chunk), K))``.
        """
        base = jax.random.PRNGKey(0) if key is None else key
        ids = list(doc_ids)
        for i, lo in enumerate(range(0, len(ids), batch_size)):
            chunk = ids[lo: lo + batch_size]
            w, c = bucketize(corpus, chunk, pad_multiple=bucket_multiple)
            if len(chunk) < batch_size:      # tail: pad with empty docs
                padding = batch_size - len(chunk)
                w = np.concatenate([w, np.zeros((padding, w.shape[1]),
                                                w.dtype)])
                c = np.concatenate([c, np.zeros((padding, c.shape[1]),
                                                c.dtype)])
            theta = self.infer(w, c, key=jax.random.fold_in(base, i))
            yield chunk, theta[: len(chunk)]


def serve_lda(args) -> None:
    cfg = LDAConfig(num_topics=args.topics, vocab_size=args.vocab)
    store = ParameterStore(args.workdir, num_topics=args.topics,
                           vocab_capacity=args.vocab,
                           buffer_rows=args.buffer_rows)
    if store.phi_k.sum() == 0:
        raise SystemExit(
            f"no trained φ̂ under {args.workdir}; run launch/train.py first"
        )
    server = TopicServer(store, cfg, active_topics=args.active_topics)
    corpus, _ = synthetic_lda_corpus(args.requests, args.vocab,
                                     args.topics, seed=123)
    ids = list(range(corpus.num_docs))
    t0 = time.time()
    for chunk, theta in server.infer_stream(corpus, ids, args.batch):
        top = np.argsort(-theta, axis=1)[:, :3]
        if chunk[0] == ids[0]:
            for d in range(min(4, len(chunk))):
                mix = ", ".join(
                    f"k{int(k)}:{theta[d, k]:.2f}" for k in top[d]
                )
                print(f"  doc{chunk[d]:4d} top topics: {mix}")
    dt = time.time() - t0
    print(f"served {len(ids)} docs in {dt:.2f}s "
          f"({len(ids)/dt:.1f} docs/s, batch={args.batch}, "
          f"{server.last_sweeps} fixed-point sweeps on the last batch)")


def serve_lm(args) -> None:
    cfg = ARCHS[args.arch].reduced()
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    B, prompt_len, gen = args.batch, 16, args.gen_tokens

    batch = {"tokens": jnp.ones((B, prompt_len), jnp.int32)}
    if cfg.frontend == "image_patches":
        batch["image_embeds"] = jnp.ones(
            (B, cfg.image_tokens, cfg.d_model), jnp.float32) * 0.01
    logits, pre_caches = model.prefill(params, batch)
    cache = model.init_cache(B, prompt_len + gen)
    cache = jax.tree.map(
        lambda dst, src: jax.lax.dynamic_update_slice(
            dst, src.astype(dst.dtype), (0,) * dst.ndim
        ) if dst.ndim == src.ndim else dst,
        cache, pre_caches,
    )

    @jax.jit
    def step(params, cache, tok, pos):
        b = {"tokens": tok}
        if cfg.frontend == "image_patches":
            b["image_embeds"] = batch["image_embeds"]
        lg, cache = model.decode_step(params, cache, b, pos)
        nxt = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache

    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out: List[np.ndarray] = [np.asarray(tok)]
    t0 = time.time()
    for i in range(gen):
        tok, cache = step(params, cache, tok, jnp.int32(prompt_len + i))
        out.append(np.asarray(tok))
    dt = time.time() - t0
    print(f"{args.arch}: generated {gen}×{B} tokens in {dt:.2f}s "
          f"({B*gen/dt:.1f} tok/s); sample: {np.concatenate(out,1)[0][:16]}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=LDA_ARCH)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--topics", type=int, default=100)
    ap.add_argument("--vocab", type=int, default=5000)
    ap.add_argument("--buffer-rows", type=int, default=2048)
    ap.add_argument("--active-topics", type=int, default=0,
                    help="restrict each word's fit support to its top-A "
                         "topics by trained φ mass (0 = dense fit)")
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--gen-tokens", type=int, default=32)
    args = ap.parse_args()
    if args.arch == LDA_ARCH:
        serve_lda(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
