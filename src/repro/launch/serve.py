"""Serving driver — topic inference for unseen documents (the paper's
deployment mode) and LM decode on reduced configs.

LDA serving = the E-step with FROZEN φ̂: per request batch, fit θ̂ only
(fixed-point iterations), return the per-document topic mixture.  This is
exactly the paper's test-time protocol (§2.4) and runs with the same
vocab-streamed parameter access as training.
"""
from __future__ import annotations

import argparse
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, LDA_ARCH
from repro.core import LDAConfig, ParameterStore
from repro.core.perplexity import fit_theta_fixed_phi
from repro.core import em
from repro.core.types import MinibatchData
from repro.data import synthetic_lda_corpus
from repro.models import build
from repro.sparse.docword import bucketize, localize_vocab


class TopicServer:
    """Batched topic-mixture inference against a (possibly disk-backed) φ̂."""

    def __init__(self, store: ParameterStore, cfg: LDAConfig,
                 fit_sweeps: int = 50):
        self.store = store
        self.cfg = cfg
        self.fit_sweeps = fit_sweeps
        self.key = jax.random.PRNGKey(0)

    def infer(self, word_ids: np.ndarray, counts: np.ndarray) -> np.ndarray:
        """(B, L) docs -> (B, K) normalized topic mixtures θ."""
        uniq, local = localize_vocab(word_ids)
        rows = self.store.fetch_rows(uniq)                     # streamed φ̂
        phi_k = jnp.asarray(self.store.phi_k, jnp.float32)
        # local (W_s, K) view: the smoothing mass must use the global W
        phi_norm = em.normalize_phi(
            jnp.asarray(rows), phi_k, self.cfg, vocab_size=self.cfg.W
        )
        batch = MinibatchData(jnp.asarray(local), jnp.asarray(counts))
        rows_tok = em.gather_phi_rows(phi_norm, batch.word_ids)
        self.key, sub = jax.random.split(self.key)
        theta = fit_theta_fixed_phi(sub, batch, rows_tok, self.cfg,
                                    self.fit_sweeps)
        return np.asarray(em.normalize_theta(theta, self.cfg))


def serve_lda(args) -> None:
    cfg = LDAConfig(num_topics=args.topics, vocab_size=args.vocab)
    store = ParameterStore(args.workdir, num_topics=args.topics,
                           vocab_capacity=args.vocab,
                           buffer_rows=args.buffer_rows)
    if store.phi_k.sum() == 0:
        raise SystemExit(
            f"no trained φ̂ under {args.workdir}; run launch/train.py first"
        )
    server = TopicServer(store, cfg)
    corpus, _ = synthetic_lda_corpus(args.requests, args.vocab,
                                     args.topics, seed=123)
    ids = list(range(corpus.num_docs))
    t0 = time.time()
    for lo in range(0, len(ids), args.batch):
        chunk = ids[lo: lo + args.batch]
        w, c = bucketize(corpus, chunk)
        theta = server.infer(w, c)
        top = np.argsort(-theta, axis=1)[:, :3]
        if lo == 0:
            for d in range(min(4, len(chunk))):
                mix = ", ".join(
                    f"k{int(k)}:{theta[d, k]:.2f}" for k in top[d]
                )
                print(f"  doc{chunk[d]:4d} top topics: {mix}")
    dt = time.time() - t0
    print(f"served {len(ids)} docs in {dt:.2f}s "
          f"({len(ids)/dt:.1f} docs/s, batch={args.batch})")


def serve_lm(args) -> None:
    cfg = ARCHS[args.arch].reduced()
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    B, prompt_len, gen = args.batch, 16, args.gen_tokens

    batch = {"tokens": jnp.ones((B, prompt_len), jnp.int32)}
    if cfg.frontend == "image_patches":
        batch["image_embeds"] = jnp.ones(
            (B, cfg.image_tokens, cfg.d_model), jnp.float32) * 0.01
    logits, pre_caches = model.prefill(params, batch)
    cache = model.init_cache(B, prompt_len + gen)
    cache = jax.tree.map(
        lambda dst, src: jax.lax.dynamic_update_slice(
            dst, src.astype(dst.dtype), (0,) * dst.ndim
        ) if dst.ndim == src.ndim else dst,
        cache, pre_caches,
    )

    @jax.jit
    def step(params, cache, tok, pos):
        b = {"tokens": tok}
        if cfg.frontend == "image_patches":
            b["image_embeds"] = batch["image_embeds"]
        lg, cache = model.decode_step(params, cache, b, pos)
        nxt = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache

    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out: List[np.ndarray] = [np.asarray(tok)]
    t0 = time.time()
    for i in range(gen):
        tok, cache = step(params, cache, tok, jnp.int32(prompt_len + i))
        out.append(np.asarray(tok))
    dt = time.time() - t0
    print(f"{args.arch}: generated {gen}×{B} tokens in {dt:.2f}s "
          f"({B*gen/dt:.1f} tok/s); sample: {np.concatenate(out,1)[0][:16]}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=LDA_ARCH)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--topics", type=int, default=100)
    ap.add_argument("--vocab", type=int, default=5000)
    ap.add_argument("--buffer-rows", type=int, default=2048)
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--gen-tokens", type=int, default=32)
    args = ap.parse_args()
    if args.arch == LDA_ARCH:
        serve_lda(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
