"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""
from __future__ import annotations

import jax

from repro.parallel import compat


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return compat.make_mesh((data, model), ("data", "model"))
