"""Roofline-term extraction from compiled dry-run artifacts.

``compiled.cost_analysis()`` visits while-loop bodies ONCE (scan-over-layers
would be under-counted ~nblocks×), so this module walks the post-SPMD HLO
text itself:

  * computations are parsed into blocks; a call graph with *multiplicities*
    is built — while bodies are multiplied by their trip count, which XLA
    materialises as the loop-bound constant in the condition computation
    (dynamic conditions, e.g. FOEM's ΔP stop, fall back to a caller-supplied
    expected trip count);
  * per top-level op (fusion boundaries = HBM traffic): result+operand bytes
    feed the memory term; dot/conv FLOPs are computed from shapes and
    contraction dims; elementwise/reduce ops contribute out-element FLOPs;
  * collective bytes per device: all-reduce 2×result, all-gather result,
    reduce-scatter operand, all-to-all result, collective-permute result
    (ring-model wire bytes on the ICI).

Terms (v5e): compute = FLOPs/chip / 197e12, memory = HBM bytes/chip / 819e9,
collective = wire bytes/chip / 50e9.  The HLO here is already the per-device
partitioned module, so no further /chips normalisation is needed.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

# ---- hardware constants (TPU v5e) ----
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|[sufc]\d+|bf16|f8e4m3fn|f8e5m2)\[([\d,]*)\]")
_ELEMWISE = (
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "rsqrt", "sqrt", "tanh", "negate", "abs",
    "logistic", "cosine", "sine", "floor", "ceil", "round-nearest-afz",
    "select", "compare", "and", "or", "xor", "not", "clamp",
)


def _shape_bytes(dtype: str, dims: str) -> Tuple[int, int]:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n, n * _DTYPE_BYTES.get(dtype, 4)


def _all_shapes(text: str) -> List[Tuple[int, int]]:
    return [_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(text)]


@dataclasses.dataclass
class OpCosts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    coll_count: Dict[str, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int)
    )


_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def parse_computations(hlo: str) -> Dict[str, List[str]]:
    """Split HLO text into computation blocks: name -> op lines."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        s = line.strip()
        # computation headers end with "{", carry a "->" return annotation and
        # are not assignments (params may be tuple-typed: nested parens).
        if s.endswith("{") and "->" in s and " = " not in s:
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)", s)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if s.startswith("}"):
            cur = None
            continue
        if cur is not None and "=" in s:
            comps[cur].append(s)
    return comps


def _entry_name(hlo: str) -> Optional[str]:
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
    return m.group(1) if m else None


def _trip_count(cond_lines: List[str], default_trip: int) -> int:
    """Loop bound = the largest s32 constant compared in the condition."""
    best = 0
    for ln in cond_lines:
        if "constant(" in ln:
            for c in re.findall(r"constant\((\d+)\)", ln):
                best = max(best, int(c))
    return best if best > 0 else default_trip


_DEF_RE = re.compile(r"^%?([\w.\-]+)\s*=\s*")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def build_def_table(hlo: str) -> Dict[str, Tuple[int, int, List[int]]]:
    """SSA table: instruction name -> (elems, bytes, dims).

    Post-optimization HLO prints operands WITHOUT inline shapes, so operand
    sizes must be resolved through their defining instruction.
    """
    table: Dict[str, Tuple[int, int, List[int]]] = {}
    for line in hlo.splitlines():
        s = line.strip()
        m = _DEF_RE.match(s)
        if not m:
            continue
        sh = _SHAPE_RE.search(s[m.end():])
        if not sh:
            continue
        dims = [int(x) for x in sh.group(2).split(",")] if sh.group(2) else []
        n, b = _shape_bytes(sh.group(1), sh.group(2))
        table[m.group(1)] = (n, b, dims)
    return table


def _operands_of(line: str, op: str, table) -> List[Tuple[int, int, List[int]]]:
    """Resolve operand sizes from the SSA table (inline shapes if present)."""
    try:
        args = line.split(op + "(", 1)[1]
    except IndexError:
        return []
    depth, out = 1, []
    end = 0
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    args = args[:end]
    inline = _SHAPE_RE.findall(args)
    if inline:
        return [(*_shape_bytes(d, s), [int(x) for x in s.split(",")] if s else [])
                for d, s in inline]
    res = []
    for name in _OPERAND_RE.findall(args):
        if name in table:
            res.append(table[name])
    return res


def _dot_flops(line: str, table) -> float:
    # 2 × out_elems × contraction_size (contraction dims from lhs operand)
    m = re.match(r"%?[\w.\-]+\s*=\s*(.*?)\s*dot\(", line)
    if not m:
        return 0.0
    res_shapes = _all_shapes(m.group(1))
    out_elems = res_shapes[0][0] if res_shapes else 0
    ops = _operands_of(line, "dot", table)
    cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    contract = 1
    if cd and ops:
        lhs_dims = ops[0][2]
        for idx in cd.group(1).split(","):
            if idx != "" and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


def _conv_flops(line: str, table) -> float:
    m = re.match(r"%?[\w.\-]+\s*=\s*(.*?)\s*convolution\(", line)
    if not m:
        return 0.0
    res = _all_shapes(m.group(1))
    ops = _operands_of(line, "convolution", table)
    out_elems = res[0][0] if res else 0
    kernel_elems = ops[1][0] if len(ops) > 1 else 1
    gm = re.search(r"feature_group_count=(\d+)", line)
    groups = int(gm.group(1)) if gm else 1
    # per-output MACs ≈ kernel/groups … ≈ window taps for depthwise
    return 2.0 * out_elems * max(kernel_elems / max(groups, 1), 1.0)


def _fusion_memory(
    flines: List[str], res_bytes: int, opnd_bytes: int,
    opnd_sizes: Optional[List[int]] = None,
) -> Tuple[int, int]:
    """Correct a fusion op's HBM traffic for internal slicing semantics.

    * a parameter only read through (possibly bitcast/copy-aliased)
      ``dynamic-slice`` contributes its *slice* bytes, not the full buffer —
      this is how scan-over-layers reads one layer of the stacked params;
    * a fusion whose root is ``dynamic-update-slice`` writes only the update
      region (the result aliases the input buffer in place).
    """
    defs: Dict[str, int] = {}
    alias: Dict[str, str] = {}
    for il in flines:
        dm = _DEF_RE.match(il)
        if not dm:
            continue
        name = dm.group(1)
        sh = _SHAPE_RE.search(il[dm.end():])
        if sh:
            defs[name] = _shape_bytes(sh.group(1), sh.group(2))[1]
        am = re.search(
            r"=\s*[^=]*?\b(?:bitcast|copy|convert|transpose|reshape)\(%([\w.\-]+)",
            il,
        )
        if am:
            alias[name] = am.group(1)

    def root_of(n: str) -> str:
        seen = set()
        while n in alias and n not in seen:
            seen.add(n)
            n = alias[n]
        return n

    sliced: Dict[str, int] = {}
    other: set = set()
    dus_update: Optional[int] = None
    dus_buffer: Optional[str] = None
    for il in flines:
        dsm = re.match(
            r"%?[\w.\-]+\s*=\s*(.*?)\s*dynamic-slice\(%([\w.\-]+)", il
        )
        if dsm:
            tgt = root_of(dsm.group(2))
            sh = _all_shapes(dsm.group(1))
            if sh:
                sliced[tgt] = sliced.get(tgt, 0) + sh[0][1]
            continue
        dum = re.search(
            r"dynamic-update-slice\(%([\w.\-]+),\s*%([\w.\-]+)", il
        )
        if dum:
            dus_buffer = root_of(dum.group(1))
            dus_update = defs.get(root_of(dum.group(2)), 0)
            continue
        if " = " in il:
            tail = il.split(" = ", 1)[1]
            tail = tail.split("(", 1)[1] if "(" in tail else tail
            for pm in re.finditer(r"%([\w.\-]+)", tail):
                other.add(root_of(pm.group(1)))

    for pname, slice_bytes in sliced.items():
        if pname in other or not pname.startswith("param"):
            continue
        full = defs.get(pname)
        if full and full > slice_bytes:
            opnd_bytes -= full - slice_bytes
    if dus_update is not None and dus_buffer is not None:
        # in-place update: write update bytes; don't read the full buffer
        res_bytes = dus_update
        subtracted = False
        if dus_buffer.startswith("param") and dus_buffer not in other:
            full = defs.get(dus_buffer)
            if full:
                opnd_bytes -= full - dus_update
                subtracted = True
        if not subtracted and opnd_sizes:
            # buffer arrived as a direct operand (e.g. via a top-level copy):
            # drop the largest operand — it is the aliased in-place buffer
            big = max(opnd_sizes)
            if big > 2 * dus_update:
                opnd_bytes -= big - dus_update
    return max(res_bytes, 0), max(opnd_bytes, 0)


def analyze_hlo(
    hlo: str, *, default_trip: int = 1, expected_dynamic_trip: int = 12,
) -> OpCosts:
    comps = parse_computations(hlo)
    table = build_def_table(hlo)
    entry = _entry_name(hlo)
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda k: len(comps[k])) if comps else None
    costs = OpCosts()
    if entry is None:
        return costs

    fusion_bodies = set()
    for lines in comps.values():
        for ln in lines:
            fm = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", ln)
            if fm:
                fusion_bodies.add(fm.group(1))

    seen: Dict[str, float] = {}

    def walk(name: str, mult: float) -> None:
        if name not in comps or mult <= 0:
            return
        seen[name] = seen.get(name, 0) + mult
        for ln in comps[name]:
            opm = re.search(r"=\s*(?:\([^)]*\)|[\w\[\],{}\s]*?)\s*([a-z][\w\-]*)\(", ln)
            op = opm.group(1) if opm else ""
            if op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ln)
                cm = re.search(r"condition=%?([\w.\-]+)", ln)
                trip = _trip_count(
                    comps.get(cm.group(1), []) if cm else [],
                    expected_dynamic_trip,
                )
                if bm:
                    walk(bm.group(1), mult * trip)
                if cm:
                    walk(cm.group(1), mult * trip)
                continue
            if op in ("call", "conditional"):
                for tm in re.findall(
                    r"(?:to_apply|branch_computations=\{|true_computation|"
                    r"false_computation)=?%?([\w.\-]+)", ln
                ):
                    walk(tm, mult)
            _count_line(ln, op, mult)

    def _count_line(ln: str, op: str, mult: float) -> None:
        if op.endswith("-done"):
            return
        base_op = op[:-6] if op.endswith("-start") else op
        eq = ln.index("=") if "=" in ln else 0
        result_part = (
            ln[eq + 1: ln.index(base_op + "(")]
            if (base_op + "(") in ln else ln[eq + 1:]
        )
        res_shapes = _all_shapes(result_part)
        if not res_shapes:
            return
        res_bytes = sum(b for _, b in res_shapes)
        res_elems = sum(n for n, _ in res_shapes)
        opnd_bytes = sum(b for _, b, _ in _operands_of(ln, base_op, table))

        # ---- HBM-traffic corrections: slicing ops read only their slice ----
        if base_op in ("dynamic-slice", "gather"):
            opnd_bytes = res_bytes          # read = slice/gathered bytes
        elif base_op in ("dynamic-update-slice", "scatter"):
            # in-place update: read+write of the update region, not the buffer
            ops_sz = [b for _, b, _ in _operands_of(ln, base_op, table)]
            upd = ops_sz[1] if len(ops_sz) > 1 else res_bytes
            costs.hbm_bytes += mult * 2 * upd
            return
        elif base_op == "fusion":
            fm0 = re.search(r"calls=%?([\w.\-]+)", ln)
            if fm0 and fm0.group(1) in comps:
                res_bytes, opnd_bytes = _fusion_memory(
                    comps[fm0.group(1)], res_bytes, opnd_bytes,
                    [b for _, b, _ in _operands_of(ln, base_op, table)],
                )

        if base_op in _COLLECTIVES:
            if base_op == "all-reduce":
                wire = 2.0 * res_bytes
            elif base_op == "reduce-scatter":
                wire = max(opnd_bytes, res_bytes)
            else:
                wire = res_bytes
            costs.coll_bytes += mult * wire
            costs.coll_by_kind[base_op] += mult * wire
            costs.coll_count[base_op] += int(mult)
            return
        if base_op in ("parameter", "constant", "tuple", "get-tuple-element",
                       "bitcast", "copy-start", "copy-done", "after-all"):
            return
        # memory: fusion boundary traffic
        costs.hbm_bytes += mult * (res_bytes + max(opnd_bytes, 0))
        if base_op == "dot":
            costs.flops += mult * _dot_flops(ln, table)
        elif base_op == "convolution":
            costs.flops += mult * _conv_flops(ln, table)
        elif base_op == "fusion":
            # count the fusion's internal arithmetic: dots inside + one
            # elementwise op per output element per internal instruction
            fm = re.search(r"calls=%?([\w.\-]+)", ln)
            if fm and fm.group(1) in comps:
                inner_flops = 0.0
                for il in comps[fm.group(1)]:
                    iop = re.search(r"=\s*[\w\[\],{}\s]*?([a-z][\w\-]*)\(", il)
                    ioname = iop.group(1) if iop else ""
                    if ioname == "dot":
                        inner_flops += _dot_flops(il, table)
                    elif ioname in _ELEMWISE or ioname == "reduce":
                        ish = _all_shapes(il.split("=", 1)[1])
                        inner_flops += ish[0][0] if ish else 0
                costs.flops += mult * inner_flops
        elif base_op in _ELEMWISE or base_op in ("reduce", "reduce-window"):
            costs.flops += mult * res_elems

    walk(entry, 1.0)
    return costs


# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hbm_bytes: float
    coll_bytes: float
    coll_by_kind: Dict[str, float]
    model_flops: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (chips × HLO flops) — remat/redundancy waste."""
        tot = self.flops * self.chips
        return self.model_flops / tot if tot > 0 else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs / (chips × peak × roofline step time)."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * t)

    def summary(self) -> str:
        return (
            f"compute={self.compute_s*1e3:9.3f}ms memory={self.memory_s*1e3:9.3f}ms "
            f"collective={self.collective_s*1e3:9.3f}ms dominant={self.dominant:10s} "
            f"useful={self.useful_flops_fraction*100:5.1f}% roofline-MFU={self.mfu*100:5.1f}%"
        )


def roofline_from_hlo(
    hlo: str, *, chips: int, model_flops: float,
    expected_dynamic_trip: int = 12,
) -> Roofline:
    c = analyze_hlo(hlo, expected_dynamic_trip=expected_dynamic_trip)
    return Roofline(
        compute_s=c.flops / PEAK_FLOPS,
        memory_s=c.hbm_bytes / HBM_BW,
        collective_s=c.coll_bytes / ICI_BW,
        flops=c.flops,
        hbm_bytes=c.hbm_bytes,
        coll_bytes=c.coll_bytes,
        coll_by_kind=dict(c.coll_by_kind),
        model_flops=model_flops,
        chips=chips,
    )


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); D = tokens/step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens          # forward only
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def lda_model_flops(shape, sweeps: int = 12, active_topics: int = 16) -> float:
    """Useful FLOPs of the FOEM inner loop: the paper's 2·λkK·NNZ accounting
    (E-step multiply+normalise) + fold adds, per sweep."""
    nnz = shape.minibatch_docs * shape.bucket_len
    per_sweep = nnz * active_topics * 8.0      # eq.13 arithmetic per active topic
    return sweeps * per_sweep
