"""input_specs + jitted step builders for every (arch × shape × mesh) cell.

``input_specs`` returns weak-type-correct ``ShapeDtypeStruct`` stand-ins for
every model input (the shannon/kernels pattern): shardable, no device
allocation.  ``build_cell`` packages the step function, its abstract
arguments, and in/out shardings — consumed by the dry-run, the roofline
extractor and the perf loop.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.configs.registry import get_arch, get_shape
from repro.configs import foem_lda
from repro.core import foem as foem_lib
from repro.core.types import GlobalStats, LDAConfig, MinibatchData
from repro.models.lm import LM, build, jnp_dtype
from repro.optim.adamw import OptState, adamw_init, adamw_update
from repro.optim.schedules import cosine_warmup
from repro.parallel import sharding as shard_rules


@dataclasses.dataclass
class Cell:
    """One dry-run cell: a jittable step with abstract args + shardings."""

    arch: str
    shape: str
    kind: str                      # train | prefill | decode | lda
    fn: Callable
    abstract_args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()
    static_argnums: Tuple[int, ...] = ()

    def lower(self, mesh: Mesh):
        jitted = jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )
        with mesh:
            return jitted.lower(*self.abstract_args)


def _named(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# LM inputs
# ---------------------------------------------------------------------------

def lm_input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStructs for the model inputs of this cell."""
    B = shape.global_batch
    S = shape.seq_len if shape.kind != "decode" else 1
    dt = jnp_dtype(cfg.dtype)
    specs: Dict[str, Any] = {}
    if cfg.frontend == "audio_frames":
        specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.frontend == "image_patches":
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.image_tokens, cfg.d_model), dt
        )
    return specs


def input_specs(arch_name: str, shape_name: str) -> Dict[str, Any]:
    """Public helper (per the assignment): abstract inputs for a cell."""
    cfg = get_arch(arch_name)
    return lm_input_specs(cfg, get_shape(cfg, shape_name))


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def build_lm_cell(
    arch_name: str, shape_name: str, mesh: Mesh, *,
    overrides: Optional[dict] = None,
) -> Cell:
    cfg = get_arch(arch_name)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = get_shape(cfg, shape_name)
    dp = shard_rules.dp_axes(mesh)
    dp_entry = dp if shape.global_batch % shard_rules._dp_size(mesh) == 0 else None
    model = build(cfg, mesh=mesh, dp_spec=dp_entry)

    p_specs = shard_rules.param_pspecs(model, mesh)
    b_specs = shard_rules.batch_pspecs(cfg, shape, mesh)
    params_abs = model.abstract_params()
    batch_abs = lm_input_specs(cfg, shape)
    b_specs = {k: b_specs[k] for k in batch_abs}   # align key sets

    if shape.kind == "train":
        opt_abs = jax.eval_shape(adamw_init, params_abs)
        m_specs = (
            shard_rules.zero1_pspecs(model, mesh) if cfg.zero1 else p_specs
        )
        o_specs = OptState(mu=m_specs, nu=m_specs, count=P())
        mb = max(1, cfg.micro_batches)

        def train_step(params, opt, batch):
            if mb == 1:
                loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
            else:
                # gradient accumulation: microbatches scanned, fp32 grads.
                # The reshape must NOT move the data-sharding onto the
                # microbatch axis (XLA would re-shard batch 4× instead of
                # 16× and quadruple per-device work) — constrain explicitly.
                micro = jax.tree.map(
                    lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]),
                    batch,
                )
                micro = {
                    k: jax.lax.with_sharding_constraint(
                        v, NamedSharding(mesh, P(None, *b_specs[k]))
                    )
                    for k, v in micro.items()
                }
                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )

                def acc(carry, mbatch):
                    lsum, g = carry
                    l, gi = jax.value_and_grad(model.loss_fn)(params, mbatch)
                    g = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), g, gi
                    )
                    return (lsum + l, g), None

                (lsum, gsum), _ = jax.lax.scan(
                    acc, (jnp.float32(0.0), g0), micro
                )
                loss = lsum / mb
                grads = jax.tree.map(lambda g: g / mb, gsum)
            lr = cosine_warmup(opt.count, peak_lr=3e-4, warmup=2000,
                               total=100_000)
            new_params, new_opt = adamw_update(grads, opt, params, lr=lr)
            return loss, new_params, new_opt

        return Cell(
            arch=arch_name, shape=shape_name, kind="train",
            fn=train_step,
            abstract_args=(params_abs, opt_abs, batch_abs),
            in_shardings=(
                _named(mesh, p_specs), _named(mesh, o_specs),
                _named(mesh, b_specs),
            ),
            out_shardings=(
                NamedSharding(mesh, P()),
                _named(mesh, p_specs), _named(mesh, o_specs),
            ),
            donate_argnums=(0, 1),
        )

    if shape.kind == "prefill":
        cache_specs = shard_rules.cache_pspecs(model, shape, mesh)
        logits_spec = P(
            dp_entry, None,
            "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None,
        )

        def prefill_step(params, batch):
            return model.prefill(params, batch)

        return Cell(
            arch=arch_name, shape=shape_name, kind="prefill",
            fn=prefill_step,
            abstract_args=(params_abs, batch_abs),
            in_shardings=(_named(mesh, p_specs), _named(mesh, b_specs)),
            out_shardings=(
                NamedSharding(mesh, logits_spec),
                _named(mesh, cache_specs),
            ),
        )

    # decode: one new token against a seq_len-deep cache
    cache_abs = model.abstract_cache(shape.global_batch, shape.seq_len)
    cache_specs = shard_rules.cache_pspecs(model, shape, mesh)
    logits_spec = P(
        dp_entry, None,
        "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None,
    )
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)

    def decode_step(params, caches, batch, pos):
        return model.decode_step(params, caches, batch, pos)

    return Cell(
        arch=arch_name, shape=shape_name, kind="decode",
        fn=decode_step,
        abstract_args=(params_abs, cache_abs, batch_abs, pos_abs),
        in_shardings=(
            _named(mesh, p_specs), _named(mesh, cache_specs),
            _named(mesh, b_specs), NamedSharding(mesh, P()),
        ),
        out_shardings=(
            NamedSharding(mesh, logits_spec), _named(mesh, cache_specs),
        ),
        donate_argnums=(1,),
    )


# ---------------------------------------------------------------------------
# the paper's LDA cells
# ---------------------------------------------------------------------------

def build_lda_cell(
    shape_name: str, mesh: Mesh, *,
    shard_topics: bool = True, active_topics: int = 16,
    overrides: Optional[dict] = None, impl: str = "pjit",
) -> Cell:
    shp = next(s for s in foem_lda.LDA_SHAPES if s.name == shape_name)
    cfg = foem_lda.lda_config(shp, active_topics=active_topics)
    if impl == "sharded":
        overrides = dict(overrides or {})
        overrides.setdefault("topk_shards", mesh.shape["model"])
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    dp = shard_rules.dp_axes(mesh)

    batch_abs = MinibatchData(
        word_ids=jax.ShapeDtypeStruct(
            (shp.minibatch_docs, shp.bucket_len), jnp.int32
        ),
        counts=jax.ShapeDtypeStruct(
            (shp.minibatch_docs, shp.bucket_len), jnp.float32
        ),
    )
    stats_abs = jax.eval_shape(lambda: GlobalStats.zeros(cfg))
    stats_specs = shard_rules.lda_pspecs(mesh, shard_topics=shard_topics)
    batch_specs = MinibatchData(word_ids=P(dp, None), counts=P(dp, None))
    key_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)

    if impl == "sharded":
        from repro.core.foem_sharded import foem_step_sharded

        def lda_step(key, batch, stats):
            return foem_step_sharded(key, batch, stats, cfg, mesh)
    else:
        def lda_step(key, batch, stats):
            new_stats, local, diag = foem_lib.foem_step(key, batch, stats, cfg)
            return new_stats, diag.final_train_ppl

    return Cell(
        arch="foem-lda", shape=shape_name, kind="lda",
        fn=lda_step,
        abstract_args=(key_abs, batch_abs, stats_abs),
        in_shardings=(
            NamedSharding(mesh, P()), _named(mesh, batch_specs),
            _named(mesh, stats_specs),
        ),
        out_shardings=(
            _named(mesh, stats_specs), NamedSharding(mesh, P()),
        ),
        donate_argnums=(2,),
    )
