"""Training driver.

Two modes:
  * ``--arch foem-lda`` — the paper's system: streaming FOEM with the
    disk-backed ParameterStore (single-host runtime; pjit path available via
    --device-resident for corpora whose φ̂ fits device memory).
  * ``--arch <lm-arch>`` — reduced-config LM training on synthetic token
    streams (the end-to-end substrate exercise; production sizes are
    dry-run-only on CPU).

Fault tolerance: checkpoints every --ckpt-every steps (atomic, resharding-
capable); ``--resume`` restarts from the latest checkpoint + data cursor.
Kill the process mid-run and relaunch with --resume to see it.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs.registry import ARCHS, LDA_ARCH
from repro.core import FOEMTrainer, LDAConfig, ParameterStore
from repro.core.perplexity import predictive_perplexity, split_heldout_counts
from repro.core.types import MinibatchData
from repro.data import synthetic_lda_corpus, synthetic_token_stream
from repro.models import build
from repro.optim import adamw_init, adamw_update, cosine_warmup
from repro.sparse import MinibatchStream
from repro.sparse.docword import bucketize


def train_lda(args) -> None:
    cfg = LDAConfig(
        num_topics=args.topics,
        vocab_size=args.vocab,
        active_topics=args.active_topics,
        iem_blocks=args.iem_blocks,
        max_sweeps=args.max_sweeps,
    )
    corpus, _ = synthetic_lda_corpus(
        args.docs, args.vocab, args.topics_true or args.topics,
        mean_doc_len=args.doc_len, seed=args.seed,
    )
    rng = np.random.default_rng(args.seed)
    train, test = corpus.split_train_test(max(args.docs // 10, 8), rng)
    store = ParameterStore(
        args.workdir, num_topics=args.topics, vocab_capacity=args.vocab,
        buffer_rows=args.buffer_rows,
    )
    trainer = FOEMTrainer(
        cfg, store, seed=args.seed, checkpoint_every=args.ckpt_every,
        algorithm=args.algorithm, prefetch_depth=args.prefetch_depth,
    )
    start = trainer.resume_step() if args.resume else 0
    if start:
        print(f"[resume] continuing from minibatch cursor {start}")
    stream = MinibatchStream(
        train, args.minibatch, seed=args.seed + start, epochs=None
    )
    t0 = time.time()

    def report(m):
        if m.step % args.log_every == 0:
            pf = "+" if m.prefetch_hit else "-"
            print(
                f"step {m.step:5d} sweeps={m.sweeps:2d} "
                f"train_ppl={m.train_ppl:9.2f} io r/w={m.disk_reads}/"
                f"{m.disk_writes} hits={m.buffer_hits} pf{pf} "
                f"overlap={m.overlap_seconds*1e3:5.1f}ms {m.seconds:5.2f}s"
            )

    trainer.fit_stream(iter(stream), max_steps=args.steps, callback=report)
    print(f"trained {args.steps} minibatches in {time.time()-t0:.1f}s")

    # held-out predictive perplexity (paper eq. 21)
    ids = list(range(test.num_docs))
    w, c = bucketize(test, ids)
    est_c, ev_c = split_heldout_counts(c, rng)
    phi = jnp.asarray(store.dense_phi())
    pad = cfg.W - phi.shape[0]
    if pad > 0:
        phi = jnp.pad(phi, ((0, pad), (0, 0)))
    ppl = predictive_perplexity(
        jax.random.PRNGKey(0),
        MinibatchData(jnp.asarray(w), jnp.asarray(est_c)),
        MinibatchData(jnp.asarray(w), jnp.asarray(ev_c)),
        phi, jnp.asarray(store.phi_k, jnp.float32), cfg,
    )
    print(f"predictive perplexity (eq. 21): {float(ppl):.2f}")


def train_lm(args) -> None:
    cfg = ARCHS[args.arch].reduced()
    model = build(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init_params(key)
    opt = adamw_init(params)
    step0 = 0
    if args.resume and latest_step(args.workdir) is not None:
        step0, (params, opt) = restore_checkpoint(args.workdir, (params, opt))
        print(f"[resume] from step {step0}")

    @jax.jit
    def train_step(params, opt, batch):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        lr = cosine_warmup(opt.count, peak_lr=1e-3, warmup=20, total=args.steps)
        params, opt = adamw_update(grads, opt, params, lr=lr)
        return loss, params, opt

    stream = synthetic_token_stream(
        args.minibatch, args.seq_len, cfg.vocab_size, seed=args.seed + step0
    )
    t0 = time.time()
    for step in range(step0 + 1, args.steps + 1):
        batch = next(stream)
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.frontend == "audio_frames":
            b["embeds"] = jax.nn.one_hot(
                b.pop("tokens") % cfg.d_model, cfg.d_model, dtype=jnp.float32
            )
        if cfg.frontend == "image_patches":
            b["image_embeds"] = jnp.ones(
                (args.minibatch, cfg.image_tokens, cfg.d_model), jnp.float32
            ) * 0.01
        loss, params, opt = train_step(params, opt, b)
        if step % args.log_every == 0:
            print(f"step {step:5d} loss={float(loss):8.4f} "
                  f"({(time.time()-t0)/step:.2f}s/step)")
        if args.ckpt_every and step % args.ckpt_every == 0:
            save_checkpoint(args.workdir, step, (params, opt))
    print(f"done: {args.steps} steps in {time.time()-t0:.1f}s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=LDA_ARCH)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--log-every", type=int, default=1)
    # LDA options
    ap.add_argument("--algorithm", default="foem", choices=["foem", "sem"])
    ap.add_argument("--topics", type=int, default=100)
    ap.add_argument("--topics-true", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=5000)
    ap.add_argument("--docs", type=int, default=2000)
    ap.add_argument("--doc-len", type=int, default=80)
    ap.add_argument("--minibatch", type=int, default=256)
    ap.add_argument("--active-topics", type=int, default=16)
    ap.add_argument("--max-sweeps", type=int, default=24)
    ap.add_argument("--iem-blocks", type=int, default=0,
                    help="0 = column-serial IEM folds (paper-faithful)")
    ap.add_argument("--buffer-rows", type=int, default=2048)
    ap.add_argument("--prefetch-depth", type=int, default=1,
                    help="minibatches fetched ahead of the device "
                         "(0 = synchronous host I/O)")
    # LM options
    ap.add_argument("--seq-len", type=int, default=128)
    args = ap.parse_args()
    os.makedirs(args.workdir, exist_ok=True)
    if args.arch == LDA_ARCH:
        train_lda(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
