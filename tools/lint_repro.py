#!/usr/bin/env python
"""Repo lint: AST rules the Fast-Online-EM reproduction holds itself to.

Pure-AST (no repro imports are executed, no jax needed), so it runs as a
cheap gating CI job next to ``python -m repro.analysis --reference``.

Rules, over every module under ``src/repro``:

  f64        no float64 literal dtype: the kernels and the budget model
             assume f32 tiles, and jax silently narrows f64 without x64 —
             a host-side numpy accumulator is fine but must say so with a
             trailing ``# lint: host-f64`` comment.
  mutable-default
             no mutable default arguments (list/dict/set literals or
             constructors) — shared-state bugs under jit tracing.
  bare-except
             no bare ``except:`` — swallows KeyboardInterrupt and the
             checkify/contract errors this PR makes load-bearing.
  kernel-doc every registered kernel entry point must document its VMEM
             budget ("VMEM") and the paper equation it implements ("eq.")
             in the entry's or module's docstring.
  blockspec  no ``pl.BlockSpec`` literal outside the modules registered in
             ``repro.analysis.contracts.KERNEL_CONTRACTS`` — a BlockSpec
             the static analyzer cannot see is an unbudgeted launch.
             (Quarantined template modules are exempt: they are not part
             of the reproduction graph.)
  module-graph
             ``repro.analysis.modules.check_module_graph`` — every module
             unreachable from the reproduction roots must be explicitly
             quarantined, and the quarantine list must not rot.

Exit status: number of violation classes hit (0 == clean).
"""
from __future__ import annotations

import ast
import os
import sys
from typing import List

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
sys.path.insert(0, SRC)

# import-light by design: contracts/modules never pull jax
from repro.analysis.contracts import CONTRACT_MODULES, KERNEL_CONTRACTS  # noqa: E402
from repro.analysis.modules import (  # noqa: E402
    QUARANTINED_MODULES,
    check_module_graph,
)

HOST_F64_TAG = "lint: host-f64"


def _module_name(path: str) -> str:
    rel = os.path.relpath(path, SRC)[:-len(".py")]
    parts = rel.split(os.sep)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _iter_sources():
    for dirpath, _, files in os.walk(os.path.join(SRC, "repro")):
        for fn in sorted(files):
            if fn.endswith(".py"):
                path = os.path.join(dirpath, fn)
                with open(path, "r", encoding="utf-8") as f:
                    text = f.read()
                yield path, _module_name(path), text, ast.parse(text, path)


def _rel(path: str) -> str:
    return os.path.relpath(path, REPO)


def check_f64(path, module, text, tree) -> List[str]:
    if "jax_enable_x64" in text:
        return []  # module opts into x64 explicitly
    lines = text.splitlines()
    out = []
    for node in ast.walk(tree):
        is_f64 = (
            isinstance(node, ast.Attribute) and node.attr == "float64"
        ) or (
            isinstance(node, ast.Name) and node.id == "float64"
        ) or (
            isinstance(node, ast.Constant) and node.value == "float64"
        )
        if not is_f64:
            continue
        line = lines[node.lineno - 1]
        if HOST_F64_TAG in line:
            continue
        out.append(
            f"{_rel(path)}:{node.lineno}: [f64] float64 without x64 — "
            f"annotate a host-only accumulator with `# {HOST_F64_TAG}` "
            f"or narrow to the f32 tile dtype"
        )
    return out


_MUTABLE_CALLS = {"list", "dict", "set", "defaultdict", "OrderedDict"}


def check_mutable_defaults(path, module, text, tree) -> List[str]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for d in list(node.args.defaults) + [
            d for d in node.args.kw_defaults if d is not None
        ]:
            bad = isinstance(d, (ast.List, ast.Dict, ast.Set)) or (
                isinstance(d, ast.Call)
                and isinstance(d.func, ast.Name)
                and d.func.id in _MUTABLE_CALLS
            )
            if bad:
                out.append(
                    f"{_rel(path)}:{d.lineno}: [mutable-default] "
                    f"{node.name}() has a mutable default argument — "
                    f"default to None and build inside"
                )
    return out


def check_bare_except(path, module, text, tree) -> List[str]:
    return [
        f"{_rel(path)}:{node.lineno}: [bare-except] bare `except:` — "
        f"name the exception (it would swallow contract/sanitizer errors)"
        for node in ast.walk(tree)
        if isinstance(node, ast.ExceptHandler) and node.type is None
    ]


def check_kernel_docs(path, module, text, tree) -> List[str]:
    entries = {
        c.entry: c for c in KERNEL_CONTRACTS.values() if c.module == module
    }
    if not entries:
        return []
    mod_doc = ast.get_docstring(tree) or ""
    out = []
    for node in tree.body:
        if not isinstance(node, ast.FunctionDef):
            continue
        if node.name not in entries:
            continue
        doc = (ast.get_docstring(node) or "") + "\n" + mod_doc
        missing = [tag for tag in ("VMEM", "eq.") if tag not in doc]
        if missing:
            out.append(
                f"{_rel(path)}:{node.lineno}: [kernel-doc] registered "
                f"kernel entry {node.name}() must document "
                f"{' and '.join(missing)} in its (or the module's) "
                f"docstring"
            )
    return out


def check_blockspec(path, module, text, tree) -> List[str]:
    if module in CONTRACT_MODULES or module in QUARANTINED_MODULES:
        return []
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr == "BlockSpec":
            out.append(
                f"{_rel(path)}:{node.lineno}: [blockspec] pl.BlockSpec "
                f"outside a registered kernel contract module — register "
                f"the launch in repro.analysis.contracts so the static "
                f"analyzer budgets it"
            )
    return out


RULES = (
    check_f64,
    check_mutable_defaults,
    check_bare_except,
    check_kernel_docs,
    check_blockspec,
)


def run_lint() -> List[str]:
    violations: List[str] = []
    for path, module, text, tree in _iter_sources():
        for rule in RULES:
            violations.extend(rule(path, module, text, tree))
    graph_violations, _ = check_module_graph(SRC)
    violations.extend(f"module-graph: {v}" for v in graph_violations)
    return violations


def main() -> int:
    violations = run_lint()
    for v in violations:
        print(v)
    classes = {v.split("[")[1].split("]")[0] if "[" in v else "module-graph"
               for v in violations}
    print(f"lint_repro: {len(violations)} violation(s) "
          f"in {len(classes)} class(es)" if violations
          else "lint_repro: clean")
    return len(classes)


if __name__ == "__main__":
    sys.exit(main())
